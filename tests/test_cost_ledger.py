"""Property tests on the per-tenant cost ledger (admission + settlement).

Invariants the production hardening leans on:
  * spend conservation — the ledger's per-tenant/per-arm attribution sums
    to exactly what the routed requests were charged, including failover
    re-routes (the effective schedule charges the arm actually invoked);
  * tenant-total additivity — the same multiset of requests reaches the
    same per-tenant totals under any interleaved submission order;
  * hard budgets — no admitted request ever pushes a tenant past its
    limit, under any mix of admissions, downgrades and rejections, and
    every reservation is released by settlement.

Runs on the real ``hypothesis`` engine when installed, else on the
in-repo ``_hypolite`` fallback — scripts/ci.sh fails if these skip.
"""
import dataclasses

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container: see requirements-test.txt
    from _hypolite import given, settings, strategies as st

from repro.core.clustering import kmeans
from repro.core.estimation import SuccessProbEstimator
from repro.data import OracleWorkload
from repro.distributed.fault import FaultPolicy
from repro.serving import BatchScheduler, CostLedger, PoolEngine, Request, ThriftRouter


@dataclasses.dataclass
class TabularArm:
    name: str
    cost: float
    resp: np.ndarray

    def classify_batch(self, queries) -> np.ndarray:
        return self.resp[np.asarray(queries, np.int64)]

    def latency_s(self, batch: int) -> float:
        return 1e-6 * self.cost * batch


def _build_pool(K=4, L=8, clusters=5, B=96, seed=3):
    wl = OracleWorkload(num_classes=K, num_clusters=clusters, num_arms=L, seed=seed)
    T, emb, _ = wl.response_table(60 * clusters, seed=seed + 1)
    assign, _ = kmeans(emb, clusters, seed=0)
    est = SuccessProbEstimator(T, emb, assign)
    rng = np.random.default_rng(seed + 2)
    qcid, qemb, qlab = wl.sample_queries(B, rng)
    R = np.stack(
        [
            wl.invoke_batch(a, qcid, qlab, np.random.default_rng(seed + 100 + a))
            for a in range(L)
        ]
    )
    engine = PoolEngine(
        [TabularArm(f"t{a}", float(wl.costs[a]), R[a]) for a in range(L)]
    )
    router = ThriftRouter(engine, est, num_classes=K)
    return engine, router, qemb


# one deterministic pool shared by every example (the ledger under test is
# rebuilt per example; routing itself is read-only and cache-warm)
_ENGINE, _ROUTER, _QEMB = _build_pool()
_TIERS = np.quantile(_ENGINE.costs, [0.35, 0.6, 0.85]) * 2.5
_TENANTS = np.asarray(["acme", "zen", "umbrella", "wayne"], object)


def _sched(ledger=True, **kw):
    return BatchScheduler(
        _ROUTER, max_wait_s=0.0, ledger=ledger,
        budget_tiers=_TIERS.tolist(), **kw,
    )


# ---------------------------------------------------------------------------
# Spend conservation (with and without injected faults)
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10**6), st.integers(1, 96), st.booleans())
def test_spend_conservation_per_request_and_per_arm(seed, n, faulty):
    """sum(per-request charges) == ledger spend == sum(per-arm attribution)
    == (arm invocation counts) . (arm costs) — faulted runs included."""
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, _QEMB.shape[0], size=n)
    budgets = rng.choice(_TIERS, size=n)
    tenants = rng.choice(_TENANTS, size=n)
    if faulty:
        _ENGINE.fault_policy = FaultPolicy(
            len(_ENGINE.arms), 4, seed=seed % 997
        ).set_arms([0, 2, 5], timeout=0.25, error=0.15)
    try:
        sched = _sched(max_batch=int(rng.integers(8, 64)))
        blk = sched.submit_many(rows, _QEMB[rows], budgets, tenant=tenants)
        sched.drain()
    finally:
        _ENGINE.fault_policy = None
    led = sched.ledger
    assert np.isclose(led.total_spent, float(blk.costs.sum()), rtol=1e-12, atol=1e-18)
    by_arm_total = np.zeros(len(_ENGINE.arms))
    for name, ent in led.tenants().items():
        sel = tenants == name
        assert np.isclose(ent["spent"], float(blk.costs[sel].sum()),
                          rtol=1e-12, atol=1e-18)
        assert np.isclose(ent["by_arm"].sum(), ent["spent"], rtol=1e-12, atol=1e-18)
        assert ent["requests"] == int(sel.sum())
        assert ent["reserved"] == 0.0          # every reservation settled
        by_arm_total += ent["by_arm"]
    # cross-check attribution against the engine's invocation totals
    # (feedback/probes off: arm_query_totals is exactly the served cells)
    np.testing.assert_allclose(
        by_arm_total, sched.arm_query_totals * _ENGINE.costs,
        rtol=1e-12, atol=1e-18,
    )
    assert led.total_reserved == 0.0


# ---------------------------------------------------------------------------
# Tenant-total additivity under interleaved submission orders
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10**6), st.integers(2, 48))
def test_tenant_totals_invariant_to_submission_interleaving(seed, n):
    """Any permutation of the same requests lands identical per-tenant
    spend, request counts and per-arm attribution (deterministic arms: a
    request's charge is a function of (query, budget) alone)."""
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, _QEMB.shape[0], size=n)
    budgets = rng.choice(_TIERS, size=n)
    tenants = rng.choice(_TENANTS[:3], size=n)
    perm = rng.permutation(n)

    totals = []
    for order in (np.arange(n), perm):
        sched = _sched(max_batch=int(rng.integers(4, 32)))
        for i in order:
            sched.submit(Request(
                payload=int(rows[i]), embedding=_QEMB[rows[i]],
                budget=float(budgets[i]), tenant=str(tenants[i]),
            ))
        sched.drain()
        totals.append(sched.ledger.tenants())
    a, b = totals
    assert set(a) == set(b)
    for name in a:
        assert np.isclose(a[name]["spent"], b[name]["spent"], rtol=1e-12, atol=1e-18)
        assert a[name]["requests"] == b[name]["requests"]
        np.testing.assert_allclose(
            a[name]["by_arm"], b[name]["by_arm"], rtol=1e-12, atol=1e-18
        )


# ---------------------------------------------------------------------------
# Hard budgets: never exceeded, under admission/downgrade/rejection mixes
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10**6), st.integers(1, 64), st.floats(0.0, 12.0))
def test_hard_budget_never_exceeded(seed, n, headroom):
    """For every tenant: spent <= limit always; downgrades only ever lower
    a request's budget; rejected requests complete with zero cost; and the
    accounting identity admitted == settled + rejected holds."""
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, _QEMB.shape[0], size=n)
    budgets = rng.choice(_TIERS, size=n)
    tenants = rng.choice(_TENANTS, size=n)
    ledger = CostLedger(num_arms=len(_ENGINE.arms))
    # tight, headroom-scaled limits: some tenants afford a few requests,
    # some afford none, some are unlimited
    for i, name in enumerate(_TENANTS):
        if i == len(_TENANTS) - 1:
            continue                            # one unlimited tenant
        ledger.set_limit(str(name), float(_TIERS[0]) * headroom * (i + 0.3))
    sched = _sched(ledger=ledger, max_batch=int(rng.integers(8, 48)))
    blk = sched.submit_many(rows, _QEMB[rows], budgets, tenant=tenants)
    sched.drain()
    assert blk.done()

    rejected = blk.modes == "rejected"
    assert (blk.costs[rejected] == 0.0).all()
    assert (blk.predictions[rejected] == -1).all()
    # downgrades never raise a budget
    assert (blk.budgets <= budgets + 1e-15).all()
    for name, ent in ledger.tenants().items():
        assert ent["spent"] <= ent["limit"] + 1e-12, (name, ent)
        assert ent["reserved"] == 0.0
        sel = tenants == name
        assert ent["requests"] + ent["rejected"] == int(sel.sum())
        assert np.isclose(ent["spent"], float(blk.costs[sel].sum()),
                          rtol=1e-12, atol=1e-18)
    st_ = sched.stats
    assert st_["completed"] == n
    assert st_["ledger_rejected"] == int(rejected.sum())
    assert st_["ledger_downgraded"] == int(
        ((blk.budgets < budgets) & ~rejected).sum()
    )


def test_ledger_disabled_is_zero_overhead_and_bit_identical():
    """ledger=None (default): no tenant plumbing in the results — outputs
    bit-identical to a ledger-bearing scheduler with unlimited tenants."""
    rng = np.random.default_rng(5)
    rows = rng.integers(0, _QEMB.shape[0], size=64)
    budgets = rng.choice(_TIERS, size=64)
    s_off = _sched(ledger=None, max_batch=32)
    s_on = _sched(ledger=True, max_batch=32)
    b_off = s_off.submit_many(rows, _QEMB[rows], budgets)
    b_on = s_on.submit_many(rows, _QEMB[rows], budgets,
                            tenant=rng.choice(_TENANTS, size=64))
    s_off.drain()
    s_on.drain()
    np.testing.assert_array_equal(b_off.predictions, b_on.predictions)
    np.testing.assert_allclose(b_off.costs, b_on.costs, rtol=0, atol=0)
    np.testing.assert_array_equal(b_off.stop_waves, b_on.stop_waves)
    assert "ledger_spent" not in s_off.stats
    assert s_on.stats["ledger_rejected"] == 0


# ---------------------------------------------------------------------------
# QPS rate limits: token-bucket admission with an injectable clock
# ---------------------------------------------------------------------------


class _FakeClock:
    """Deterministic clock for the token bucket: time moves only when the
    test says so."""

    def __init__(self, t=0.0):
        self.t = float(t)

    def advance(self, dt):
        self.t += float(dt)

    def __call__(self):
        return self.t


def test_rate_limit_rejects_like_budget_rejection():
    """A rate-limited request takes the identical reject path a budget
    rejection does — prediction -1, zero cost, mode 'rejected' — and the
    ``ledger_rate_limited`` stat counts it (globally and per tenant)."""
    clock = _FakeClock()
    ledger = CostLedger(num_arms=len(_ENGINE.arms), clock=clock)
    ledger.set_rate_limit("acme", qps=1.0, burst=2.0)
    sched = _sched(ledger=ledger, max_batch=16)
    rows = np.arange(8)
    blk = sched.submit_many(rows, _QEMB[rows], float(_TIERS[-1]),
                            tenant="acme")
    sched.drain()
    # burst=2 tokens, no time passes inside the batch: exactly 2 admitted
    rej = blk.modes == "rejected"
    assert int((~rej).sum()) == 2
    assert (blk.predictions[rej] == -1).all()
    assert (blk.costs[rej] == 0.0).all()
    assert (blk.stop_waves[rej] == 0).all()
    st_ = sched.stats
    assert st_["completed"] == 8                   # rejected rows complete
    assert st_["ledger_rate_limited"] == 6
    assert st_["ledger_rejected"] == 0             # budget path untouched
    assert ledger.tenant("acme")["rate_limited"] == 6
    # refill is capped at burst: +3s at 1 qps refills to min(2, 3) tokens
    clock.advance(3.0)
    blk2 = sched.submit_many(rows[:4], _QEMB[rows[:4]], float(_TIERS[-1]),
                             tenant="acme")
    sched.drain()
    assert int((blk2.modes != "rejected").sum()) == 2
    assert ledger.tenant("acme")["rate_limited"] == 8


@settings(max_examples=20, deadline=None)
@given(
    st.floats(0.5, 8.0),                          # qps
    st.integers(1, 6),                            # burst
    st.integers(1, 30),                           # attempts
    st.floats(0.0, 1.0),                          # gap between attempts (s)
)
def test_rate_limit_bucket_conservation(qps, burst, n, gap):
    """Token conservation: admissions can never exceed the bucket's burst
    capacity plus what the clock refilled, at any prefix of the attempt
    stream — and unlimited tenants never consult the clock."""
    clock = _FakeClock()
    ledger = CostLedger(clock=clock)
    ledger.set_rate_limit("acme", qps=qps, burst=float(burst))
    admitted = 0
    for k in range(n):
        if ledger.allow_request("acme"):
            admitted += 1
        assert admitted <= burst + qps * (clock.t) + 1e-9
        clock.advance(gap)
    # an unlimited tenant is admission-free regardless of the clock
    assert all(ledger.allow_request("zen") for _ in range(10))
    assert ledger.tenant("zen")["rate_limited"] == 0


# ---------------------------------------------------------------------------
# Persistence: snapshot()/restore() across a simulated restart
# ---------------------------------------------------------------------------


def test_snapshot_restore_json_roundtrip_mid_workload():
    """Snapshot the ledger MID-workload (reservations outstanding), kill
    the scheduler, json-round-trip the state, restore, and finish the
    stream on a new scheduler: ``spent + reserved <= limit`` holds at
    every boundary, realized spend/counters survive exactly, and the
    orphaned reservations stay conservatively held."""
    import json

    rng = np.random.default_rng(17)
    rows = rng.integers(0, _QEMB.shape[0], size=48)
    budgets = rng.choice(_TIERS, size=48)
    limit = float(_TIERS[-1]) * 40
    ledger = CostLedger(num_arms=len(_ENGINE.arms))
    ledger.set_limit("acme", limit)
    ledger.set_rate_limit("acme", qps=10_000.0)    # finite: exercises enc
    sched = _sched(ledger=ledger, max_batch=16)
    sched.submit_many(rows, _QEMB[rows], budgets, tenant="acme")
    sched._dispatch_batch()                        # one batch in flight...
    ent = ledger.tenant("acme")
    assert ent["reserved"] > 0.0                   # ...reservations live
    assert ent["spent"] + ent["reserved"] <= limit + 1e-12

    # process dies here: only the JSON snapshot crosses the boundary
    payload = json.loads(json.dumps(ledger.snapshot(), allow_nan=False))
    led2 = CostLedger.restore(payload)
    e2 = led2.tenant("acme")
    for k in ("limit", "reserved", "reserved_n", "spent", "requests",
              "rejected", "downgraded", "rate_limited", "rate_limit"):
        assert e2[k] == ent[k], k
    np.testing.assert_array_equal(e2["by_arm"], ent["by_arm"])
    assert led2.default_limit == ledger.default_limit
    assert e2["spent"] + e2["reserved"] <= limit + 1e-12

    # the restarted process serves the rest of the stream
    sched2 = _sched(ledger=led2, max_batch=16)
    blk = sched2.submit_many(rows, _QEMB[rows], budgets, tenant="acme")
    sched2.drain()
    assert blk.done()
    e3 = led2.tenant("acme")
    assert e3["spent"] + e3["reserved"] <= limit + 1e-12
    # the dead process's reservations were never settled: still held
    assert e3["reserved"] >= ent["reserved"] - 1e-12
    # an unlimited-default tenant snapshot stays strict-JSON (inf -> None)
    json.dumps(CostLedger(num_arms=2).snapshot(), allow_nan=False)


# ---------------------------------------------------------------------------
# Settlement through the R-replica serving plane
# ---------------------------------------------------------------------------


def test_replica_set_settles_shared_ledger():
    """One CostLedger shared across an R=3 ReplicaSet: per-tenant spend
    equals the block's realized charges, every replica's reservations are
    released, and per-arm attribution still sums to spend."""
    from repro.serving import ReplicaSet

    rng = np.random.default_rng(23)
    n = 72
    rows = rng.integers(0, _QEMB.shape[0], size=n)
    budgets = rng.choice(_TIERS, size=n)
    tenants = rng.choice(_TENANTS, size=n)
    ledger = CostLedger(num_arms=len(_ENGINE.arms))
    rset = ReplicaSet(_ROUTER, replicas=3, max_batch=16, max_wait_s=0.0,
                      ledger=ledger, budget_tiers=_TIERS.tolist())
    blk = rset.submit_many(rows, _QEMB[rows], budgets, tenant=tenants)
    rset.drain()
    assert blk.done()
    assert np.isclose(ledger.total_spent, float(blk.costs.sum()),
                      rtol=1e-12, atol=1e-18)
    assert ledger.total_reserved == 0.0
    for name, ent in ledger.tenants().items():
        sel = tenants == name
        assert ent["requests"] == int(sel.sum())
        assert np.isclose(ent["spent"], float(blk.costs[sel].sum()),
                          rtol=1e-12, atol=1e-18)
        assert np.isclose(ent["by_arm"].sum(), ent["spent"],
                          rtol=1e-12, atol=1e-18)
    assert rset.stats["ledger_rejected"] == 0


# ---------------------------------------------------------------------------
# Restart reconciliation: restore -> release_orphans -> settle
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10**6), st.integers(8, 48))
def test_restore_release_orphans_settle_invariant(seed, n):
    """The restart-reconciliation property: snapshot a ledger with
    reservations in flight, restore it in a fresh process, release the
    orphans via ``reconcile_ledger`` BEFORE admitting new traffic, then
    serve and settle a full stream. ``spent + reserved <= limit`` holds
    per tenant at every boundary, every id-tracked reservation is either
    settled by its own scheduler or released by the reconcile pass, and
    the reclaimed headroom is actually usable (the re-run stream admits)."""
    import json

    rng = np.random.default_rng(seed)
    rows = rng.integers(0, _QEMB.shape[0], size=n)
    budgets = rng.choice(_TIERS, size=n)
    tenants = rng.choice(_TENANTS, size=n)
    limit = float(_TIERS[-1]) * n
    ledger = CostLedger(num_arms=len(_ENGINE.arms))
    for t in _TENANTS:
        ledger.set_limit(str(t), limit)
    sched = _sched(ledger=ledger, max_batch=8)
    sched.submit_many(rows, _QEMB[rows], budgets, tenant=tenants)
    sched._dispatch_batch()                    # reservations outstanding
    orphaned = 0
    for ent in ledger.tenants().values():
        assert ent["spent"] + ent["reserved"] <= limit + 1e-12
        # id-tracked ledger: the resv map tiles the reserved total exactly
        assert len(ent["resv"]) == ent["reserved_n"]
        assert np.isclose(sum(ent["resv"].values()), ent["reserved"],
                          rtol=1e-12, atol=1e-18)
        orphaned += ent["reserved_n"]

    # process dies; the snapshot (resv map included) crosses the boundary
    payload = json.loads(json.dumps(ledger.snapshot(), allow_nan=False))
    led2 = CostLedger.restore(payload)
    sched2 = _sched(ledger=led2, max_batch=8)
    released = sched2.reconcile_ledger()       # before any new traffic
    assert released == orphaned
    for ent in led2.tenants().values():
        assert ent["reserved"] == 0.0 and ent["reserved_n"] == 0
        assert not ent["resv"]
        assert ent["spent"] + ent["reserved"] <= limit + 1e-12

    blk = sched2.submit_many(rows, _QEMB[rows], budgets, tenant=tenants)
    sched2.drain()
    assert blk.done()
    for ent in led2.tenants().values():
        assert ent["spent"] + ent["reserved"] <= limit + 1e-12
        assert ent["reserved"] == 0.0 and not ent["resv"]
    # a second reconcile on a settled, idle ledger is a no-op
    assert sched2.reconcile_ledger() == 0


def test_reconcile_keeps_live_reservations():
    """reconcile_ledger on a scheduler whose own batches are in flight
    releases nothing: every reservation is id-tracked to a queued or
    in-flight request, so the live set covers them all."""
    rng = np.random.default_rng(29)
    rows = rng.integers(0, _QEMB.shape[0], size=40)
    budgets = rng.choice(_TIERS, size=40)
    ledger = CostLedger(num_arms=len(_ENGINE.arms))
    ledger.set_limit("acme", float(_TIERS[-1]) * 40)
    sched = _sched(ledger=ledger, max_batch=8)
    sched.submit_many(rows, _QEMB[rows], budgets, tenant="acme")
    sched._dispatch_batch()
    held = ledger.tenant("acme")["reserved"]
    assert held > 0.0
    assert sched.reconcile_ledger() == 0       # everything is live
    assert ledger.tenant("acme")["reserved"] == held
    sched.drain()
    assert ledger.tenant("acme")["reserved"] == 0.0


def test_replica_set_reconcile_releases_restored_orphans():
    """The set-wide reconcile: a ReplicaSet restarted onto a restored
    ledger releases the dead process's reservations in one pass and then
    serves the stream inside the reclaimed headroom."""
    import json

    from repro.serving import ReplicaSet

    rng = np.random.default_rng(31)
    rows = rng.integers(0, _QEMB.shape[0], size=48)
    budgets = rng.choice(_TIERS, size=48)
    limit = float(_TIERS[-1]) * 48
    ledger = CostLedger(num_arms=len(_ENGINE.arms))
    ledger.set_limit("acme", limit)
    sched = _sched(ledger=ledger, max_batch=16)
    sched.submit_many(rows, _QEMB[rows], budgets, tenant="acme")
    sched._dispatch_batch()
    assert ledger.tenant("acme")["reserved"] > 0.0

    led2 = CostLedger.restore(json.loads(json.dumps(ledger.snapshot())))
    rset = ReplicaSet(_ROUTER, replicas=3, max_batch=16, max_wait_s=0.0,
                      ledger=led2, budget_tiers=_TIERS.tolist())
    assert rset.reconcile_ledger() > 0
    assert led2.tenant("acme")["reserved"] == 0.0
    blk = rset.submit_many(rows, _QEMB[rows], budgets, tenant="acme")
    rset.drain()
    assert blk.done()
    ent = led2.tenant("acme")
    assert ent["spent"] + ent["reserved"] <= limit + 1e-12
    assert ent["reserved"] == 0.0
