"""Property tests on the per-tenant cost ledger (admission + settlement).

Invariants the production hardening leans on:
  * spend conservation — the ledger's per-tenant/per-arm attribution sums
    to exactly what the routed requests were charged, including failover
    re-routes (the effective schedule charges the arm actually invoked);
  * tenant-total additivity — the same multiset of requests reaches the
    same per-tenant totals under any interleaved submission order;
  * hard budgets — no admitted request ever pushes a tenant past its
    limit, under any mix of admissions, downgrades and rejections, and
    every reservation is released by settlement.

Runs on the real ``hypothesis`` engine when installed, else on the
in-repo ``_hypolite`` fallback — scripts/ci.sh fails if these skip.
"""
import dataclasses

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container: see requirements-test.txt
    from _hypolite import given, settings, strategies as st

from repro.core.clustering import kmeans
from repro.core.estimation import SuccessProbEstimator
from repro.data import OracleWorkload
from repro.distributed.fault import FaultPolicy
from repro.serving import BatchScheduler, CostLedger, PoolEngine, Request, ThriftRouter


@dataclasses.dataclass
class TabularArm:
    name: str
    cost: float
    resp: np.ndarray

    def classify_batch(self, queries) -> np.ndarray:
        return self.resp[np.asarray(queries, np.int64)]

    def latency_s(self, batch: int) -> float:
        return 1e-6 * self.cost * batch


def _build_pool(K=4, L=8, clusters=5, B=96, seed=3):
    wl = OracleWorkload(num_classes=K, num_clusters=clusters, num_arms=L, seed=seed)
    T, emb, _ = wl.response_table(60 * clusters, seed=seed + 1)
    assign, _ = kmeans(emb, clusters, seed=0)
    est = SuccessProbEstimator(T, emb, assign)
    rng = np.random.default_rng(seed + 2)
    qcid, qemb, qlab = wl.sample_queries(B, rng)
    R = np.stack(
        [
            wl.invoke_batch(a, qcid, qlab, np.random.default_rng(seed + 100 + a))
            for a in range(L)
        ]
    )
    engine = PoolEngine(
        [TabularArm(f"t{a}", float(wl.costs[a]), R[a]) for a in range(L)]
    )
    router = ThriftRouter(engine, est, num_classes=K)
    return engine, router, qemb


# one deterministic pool shared by every example (the ledger under test is
# rebuilt per example; routing itself is read-only and cache-warm)
_ENGINE, _ROUTER, _QEMB = _build_pool()
_TIERS = np.quantile(_ENGINE.costs, [0.35, 0.6, 0.85]) * 2.5
_TENANTS = np.asarray(["acme", "zen", "umbrella", "wayne"], object)


def _sched(ledger=True, **kw):
    return BatchScheduler(
        _ROUTER, max_wait_s=0.0, ledger=ledger,
        budget_tiers=_TIERS.tolist(), **kw,
    )


# ---------------------------------------------------------------------------
# Spend conservation (with and without injected faults)
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10**6), st.integers(1, 96), st.booleans())
def test_spend_conservation_per_request_and_per_arm(seed, n, faulty):
    """sum(per-request charges) == ledger spend == sum(per-arm attribution)
    == (arm invocation counts) . (arm costs) — faulted runs included."""
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, _QEMB.shape[0], size=n)
    budgets = rng.choice(_TIERS, size=n)
    tenants = rng.choice(_TENANTS, size=n)
    if faulty:
        _ENGINE.fault_policy = FaultPolicy(
            len(_ENGINE.arms), 4, seed=seed % 997
        ).set_arms([0, 2, 5], timeout=0.25, error=0.15)
    try:
        sched = _sched(max_batch=int(rng.integers(8, 64)))
        blk = sched.submit_many(rows, _QEMB[rows], budgets, tenant=tenants)
        sched.drain()
    finally:
        _ENGINE.fault_policy = None
    led = sched.ledger
    assert np.isclose(led.total_spent, float(blk.costs.sum()), rtol=1e-12, atol=1e-18)
    by_arm_total = np.zeros(len(_ENGINE.arms))
    for name, ent in led.tenants().items():
        sel = tenants == name
        assert np.isclose(ent["spent"], float(blk.costs[sel].sum()),
                          rtol=1e-12, atol=1e-18)
        assert np.isclose(ent["by_arm"].sum(), ent["spent"], rtol=1e-12, atol=1e-18)
        assert ent["requests"] == int(sel.sum())
        assert ent["reserved"] == 0.0          # every reservation settled
        by_arm_total += ent["by_arm"]
    # cross-check attribution against the engine's invocation totals
    # (feedback/probes off: arm_query_totals is exactly the served cells)
    np.testing.assert_allclose(
        by_arm_total, sched.arm_query_totals * _ENGINE.costs,
        rtol=1e-12, atol=1e-18,
    )
    assert led.total_reserved == 0.0


# ---------------------------------------------------------------------------
# Tenant-total additivity under interleaved submission orders
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10**6), st.integers(2, 48))
def test_tenant_totals_invariant_to_submission_interleaving(seed, n):
    """Any permutation of the same requests lands identical per-tenant
    spend, request counts and per-arm attribution (deterministic arms: a
    request's charge is a function of (query, budget) alone)."""
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, _QEMB.shape[0], size=n)
    budgets = rng.choice(_TIERS, size=n)
    tenants = rng.choice(_TENANTS[:3], size=n)
    perm = rng.permutation(n)

    totals = []
    for order in (np.arange(n), perm):
        sched = _sched(max_batch=int(rng.integers(4, 32)))
        for i in order:
            sched.submit(Request(
                payload=int(rows[i]), embedding=_QEMB[rows[i]],
                budget=float(budgets[i]), tenant=str(tenants[i]),
            ))
        sched.drain()
        totals.append(sched.ledger.tenants())
    a, b = totals
    assert set(a) == set(b)
    for name in a:
        assert np.isclose(a[name]["spent"], b[name]["spent"], rtol=1e-12, atol=1e-18)
        assert a[name]["requests"] == b[name]["requests"]
        np.testing.assert_allclose(
            a[name]["by_arm"], b[name]["by_arm"], rtol=1e-12, atol=1e-18
        )


# ---------------------------------------------------------------------------
# Hard budgets: never exceeded, under admission/downgrade/rejection mixes
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10**6), st.integers(1, 64), st.floats(0.0, 12.0))
def test_hard_budget_never_exceeded(seed, n, headroom):
    """For every tenant: spent <= limit always; downgrades only ever lower
    a request's budget; rejected requests complete with zero cost; and the
    accounting identity admitted == settled + rejected holds."""
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, _QEMB.shape[0], size=n)
    budgets = rng.choice(_TIERS, size=n)
    tenants = rng.choice(_TENANTS, size=n)
    ledger = CostLedger(num_arms=len(_ENGINE.arms))
    # tight, headroom-scaled limits: some tenants afford a few requests,
    # some afford none, some are unlimited
    for i, name in enumerate(_TENANTS):
        if i == len(_TENANTS) - 1:
            continue                            # one unlimited tenant
        ledger.set_limit(str(name), float(_TIERS[0]) * headroom * (i + 0.3))
    sched = _sched(ledger=ledger, max_batch=int(rng.integers(8, 48)))
    blk = sched.submit_many(rows, _QEMB[rows], budgets, tenant=tenants)
    sched.drain()
    assert blk.done()

    rejected = blk.modes == "rejected"
    assert (blk.costs[rejected] == 0.0).all()
    assert (blk.predictions[rejected] == -1).all()
    # downgrades never raise a budget
    assert (blk.budgets <= budgets + 1e-15).all()
    for name, ent in ledger.tenants().items():
        assert ent["spent"] <= ent["limit"] + 1e-12, (name, ent)
        assert ent["reserved"] == 0.0
        sel = tenants == name
        assert ent["requests"] + ent["rejected"] == int(sel.sum())
        assert np.isclose(ent["spent"], float(blk.costs[sel].sum()),
                          rtol=1e-12, atol=1e-18)
    st_ = sched.stats
    assert st_["completed"] == n
    assert st_["ledger_rejected"] == int(rejected.sum())
    assert st_["ledger_downgraded"] == int(
        ((blk.budgets < budgets) & ~rejected).sum()
    )


def test_ledger_disabled_is_zero_overhead_and_bit_identical():
    """ledger=None (default): no tenant plumbing in the results — outputs
    bit-identical to a ledger-bearing scheduler with unlimited tenants."""
    rng = np.random.default_rng(5)
    rows = rng.integers(0, _QEMB.shape[0], size=64)
    budgets = rng.choice(_TIERS, size=64)
    s_off = _sched(ledger=None, max_batch=32)
    s_on = _sched(ledger=True, max_batch=32)
    b_off = s_off.submit_many(rows, _QEMB[rows], budgets)
    b_on = s_on.submit_many(rows, _QEMB[rows], budgets,
                            tenant=rng.choice(_TENANTS, size=64))
    s_off.drain()
    s_on.drain()
    np.testing.assert_array_equal(b_off.predictions, b_on.predictions)
    np.testing.assert_allclose(b_off.costs, b_on.costs, rtol=0, atol=0)
    np.testing.assert_array_equal(b_off.stop_waves, b_on.stop_waves)
    assert "ledger_spent" not in s_off.stats
    assert s_on.stats["ledger_rejected"] == 0
