"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.belief import empty_log_belief, log_weight
from repro.core.mc import sample_pool_responses
from repro.kernels import ops, ref


@pytest.mark.parametrize("theta,L,K,C", [(512, 4, 2, 3), (1000, 8, 5, 6), (300, 12, 17, 4)])
def test_mc_correctness_sweep(theta, L, K, C):
    rng = np.random.default_rng(theta + L)
    p = rng.uniform(0.4, 0.95, L).astype(np.float32)
    resp = sample_pool_responses(jax.random.key(0), jnp.asarray(p), K, theta)
    masks = (rng.random((C, L)) < 0.6).astype(np.float32)
    w = jnp.asarray(log_weight(p, K), jnp.float32)
    empty = jnp.float32(empty_log_belief(p))
    got = ops.mc_correctness(resp, jnp.asarray(masks), w, empty, K)
    want = ref.mc_correctness_ref(resp, jnp.asarray(masks), w, empty, K)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


@pytest.mark.parametrize("G,theta,L,K,C", [(1, 512, 4, 2, 3), (5, 700, 8, 5, 4), (3, 300, 12, 7, 6)])
def test_mc_correctness_grouped_sweep(G, theta, L, K, C):
    """Grouped-mask layout vs the batched planner's bit-stable oracle,
    including ragged per-group thetas carried by the valid mask."""
    from repro.core.mc import GroupedXiEstimator

    rng = np.random.default_rng(theta + G)
    ps = rng.uniform(0.4, 0.95, (G, L))
    thetas = rng.integers(max(2, theta // 2), theta + 1, G)
    est = GroupedXiEstimator(jax.random.key(1), ps, K, thetas)
    masks = (rng.random((G, C, L)) < 0.6).astype(np.float32)
    got = ops.mc_correctness_grouped(
        jnp.asarray(est.responses), jnp.asarray(masks),
        jnp.asarray(est.log_weights), jnp.asarray(est.empty),
        jnp.asarray(est.valid), jnp.asarray(est.theta_f, jnp.float32), K,
    )
    want = ref.mc_correctness_grouped_ref(
        est.responses, masks, est.log_weights, est.empty, est.valid,
        est.theta_f, K,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-6)


@pytest.mark.parametrize("B,M,K", [(16, 4, 3), (37, 8, 5), (130, 12, 77)])
def test_belief_aggregate_sweep(B, M, K):
    rng = np.random.default_rng(B + M)
    responses = rng.integers(-1, K, (B, M)).astype(np.int32)
    w = rng.uniform(0.3, 3.0, (B, M)).astype(np.float32)
    empty = jnp.float32(-1.5)
    gb, gp = ops.belief_aggregate(jnp.asarray(responses), jnp.asarray(w), empty, K)
    wb, wp = ref.belief_aggregate_ref(jnp.asarray(responses), jnp.asarray(w), empty, K)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(wb), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(gp), np.asarray(wp))


@pytest.mark.parametrize(
    "B,S,H,G,hd,window,dtype",
    [
        (2, 128, 4, 2, 64, 0, jnp.float32),
        (1, 256, 8, 8, 32, 0, jnp.float32),
        (2, 128, 4, 1, 64, 48, jnp.float32),   # MQA + sliding window
        (1, 128, 4, 2, 64, 0, jnp.bfloat16),
    ],
)
def test_flash_attention_sweep(B, S, H, G, hd, window, dtype):
    rng = np.random.default_rng(S + H)
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, hd)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (B, S, G, hd)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (B, S, G, hd)), dtype)
    got = ops.flash_attention(q, k, v, causal=True, window=window, block_q=64, block_kv=64)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=atol
    )


@pytest.mark.parametrize("B,S,D", [(2, 64, 128), (1, 128, 512), (3, 32, 256)])
def test_rglru_scan_sweep(B, S, D):
    rng = np.random.default_rng(B + S + D)
    la = -np.abs(rng.normal(0, 0.5, (B, S, D))).astype(np.float32)
    u = rng.normal(0, 1, (B, S, D)).astype(np.float32)
    h0 = rng.normal(0, 1, (B, D)).astype(np.float32)
    gh, gl = ops.rglru_scan(la, u, h0)
    wh, wl = ref.rglru_scan_ref(jnp.asarray(la), jnp.asarray(u), jnp.asarray(h0))
    np.testing.assert_allclose(np.asarray(gh), np.asarray(wh), atol=1e-5)
    np.testing.assert_allclose(np.asarray(gl), np.asarray(wl), atol=1e-5)


def test_flash_blocks_skipped_equals_masked_baseline():
    """The skip predicate must not change numerics vs the masked baseline."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (1, 256, 2, 1, 64))[:, :, :, 0], jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 256, 2, 64)), jnp.float32)
    from repro.models.attention import blocked_attention

    got = ops.flash_attention(q, k, v, causal=True, block_q=64, block_kv=64)
    want = blocked_attention(q, k, v, causal=True, block_kv=64)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want, np.float32), atol=2e-5
    )


@pytest.mark.parametrize("B,S,Din,N", [(1, 64, 128, 8), (2, 128, 256, 16)])
def test_mamba_scan_sweep(B, S, Din, N):
    rng = np.random.default_rng(B + S + Din)
    x = rng.normal(0, 1, (B, S, Din)).astype(np.float32)
    dt = np.abs(rng.normal(0, 0.3, (B, S, Din))).astype(np.float32) + 0.01
    A = -np.abs(rng.normal(1, 0.5, (Din, N))).astype(np.float32)
    Bm = rng.normal(0, 1, (B, S, N)).astype(np.float32)
    Cm = rng.normal(0, 1, (B, S, N)).astype(np.float32)
    Dk = rng.normal(0, 1, (Din,)).astype(np.float32)
    h0 = rng.normal(0, 1, (B, Din, N)).astype(np.float32)
    gy, gh = ops.mamba_scan(x, dt, A, Bm, Cm, Dk, h0)
    wy, wh = ref.mamba_scan_ref(
        jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A), jnp.asarray(Bm),
        jnp.asarray(Cm), jnp.asarray(Dk), jnp.asarray(h0),
    )
    np.testing.assert_allclose(np.asarray(gy), np.asarray(wy), atol=3e-4)
    np.testing.assert_allclose(np.asarray(gh), np.asarray(wh), atol=3e-4)
