"""Sharding rules + multi-device integration (subprocess with forced host
devices so the main pytest process keeps its single-device view)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.distributed.sharding import AxisRules, DEFAULT_RULES


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def _rules(shape):
    r = AxisRules.__new__(AxisRules)
    r.mesh = FakeMesh(shape)
    r.rules = dict(DEFAULT_RULES)
    return r


class TestAxisRules:
    def test_divisibility_fallback(self):
        r = _rules({"data": 16, "model": 16})
        # 36 heads: tp dropped; flat 4608 feature dim: tp kept
        assert r.spec_for((4608, 4608), ("fsdp", "tp"))[1] == "model"
        assert r.spec_for((100, 36), (None, "heads"))[1] is None

    def test_no_axis_reuse(self):
        r = _rules({"data": 16, "model": 16})
        spec = r.spec_for((32, 32768, 16, 128), ("batch", "kv", "heads", None))
        # kv grabs 'model'; heads must not reuse it
        assert spec[1] == "model" and spec[2] is None

    def test_batch_maps_to_pod_and_data(self):
        r = _rules({"pod": 2, "data": 16, "model": 16})
        spec = r.spec_for((256, 4096), ("batch", None))
        assert tuple(spec[0]) == ("pod", "data")

    def test_batch_of_one_replicates(self):
        r = _rules({"data": 16, "model": 16})
        assert r.spec_for((1, 8), ("batch", None))[0] is None


_MULTIDEV_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.distributed.sharding import AxisRules, batch_specs, param_specs, use_rules
    from repro.models import LM
    from repro.training import OptimizerConfig, adamw_init, init_train_state, make_train_step

    cfg0 = get_smoke_config("smollm-135m")
    cfg = type(cfg0)(**{**cfg0.__dict__, "num_microbatches": 1})
    model = LM(cfg)
    params, opt = init_train_state(model, jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32)}

    # single-device reference
    step1 = jax.jit(make_train_step(model, OptimizerConfig(lr=1e-3)))
    p1, _, m1 = step1(params, opt, batch)

    # 2x4 mesh pjit
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = AxisRules(mesh)
    p_sh = param_specs(jax.eval_shape(lambda: params), rules)
    o_sh = param_specs(jax.eval_shape(lambda: opt), rules)
    b_sh = batch_specs(batch, rules)
    with use_rules(rules), mesh:
        stepN = jax.jit(
            make_train_step(model, OptimizerConfig(lr=1e-3)),
            in_shardings=(p_sh, o_sh, b_sh),
        )
        pN, _, mN = stepN(
            jax.device_put(params, p_sh), jax.device_put(opt, o_sh),
            jax.device_put(batch, b_sh),
        )

    max_diff = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(pN))
    )
    print(json.dumps({
        "loss1": float(m1["loss"]), "lossN": float(mN["loss"]),
        "max_param_diff": max_diff, "devices": len(jax.devices()),
    }))
    """
)


def test_pjit_train_step_matches_single_device():
    """The sharded train step must be numerically identical to local."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
        timeout=540,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["devices"] == 8
    assert res["loss1"] == pytest.approx(res["lossN"], rel=1e-5)
    assert res["max_param_diff"] < 5e-5


_DRYRUN_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import json
    from repro.launch.dryrun import dryrun_cell
    rec = dryrun_cell("smollm-135m", "decode_32k", multi_pod=True, verbose=False)
    print(json.dumps({
        "fits": rec["fits_hbm"], "chips": rec["chips"],
        "bottleneck": rec["roofline"]["bottleneck"],
        "unscoped": rec["collective_bytes"]["unscoped_while"],
    }))
    """
)


def test_multipod_dryrun_cell():
    """One multi-pod (512-chip) dry-run cell compiles inside the test suite;
    the full 40-cell x 2-mesh sweep runs via launch/dryrun.py --all."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _DRYRUN_SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(__file__)), timeout=540,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["chips"] == 512
    assert res["fits"] is True
