"""Serving runtime: router correctness, budget enforcement, scheduler."""
import numpy as np
import pytest

from repro.core.clustering import kmeans
from repro.core.estimation import SuccessProbEstimator
from repro.data import OracleWorkload
from repro.serving import BatchScheduler, OracleArm, PoolEngine, Request, ThriftRouter


@pytest.fixture(scope="module")
def setup():
    wl = OracleWorkload(num_classes=4, num_clusters=5, num_arms=8, seed=3)
    T, emb, cid = wl.response_table(600)
    assign, _ = kmeans(emb, 5, seed=0)
    est = SuccessProbEstimator(T, emb, assign)
    engine = PoolEngine([OracleArm(f"a{i}", wl, i, seed=11) for i in range(8)])
    router = ThriftRouter(engine, est, num_classes=4)
    return wl, est, engine, router


def _queries(wl, n, seed=42):
    rng = np.random.default_rng(seed)
    cid, emb, lab = wl.sample_queries(n, rng)
    return list(zip(cid, lab)), emb, lab


def test_router_respects_per_query_budget(setup):
    wl, est, engine, router = setup
    queries, emb, lab = _queries(wl, 200)
    for budget in np.quantile(engine.costs, [0.2, 0.5, 0.9]):
        res = router.route_batch(queries, emb, float(budget) * 2)
        assert (res.costs <= float(budget) * 2 + 1e-12).all()
        assert (res.costs <= res.planned_costs + 1e-12).all()


def test_router_beats_cheapest_single_arm(setup):
    wl, est, engine, router = setup
    queries, emb, lab = _queries(wl, 400)
    budget = float(np.quantile(engine.costs, 0.7)) * 2
    res = router.route_batch(queries, emb, budget)
    acc = (res.predictions == lab).mean()
    # cheapest arm alone
    rng = np.random.default_rng(9)
    cheap = np.argmin(engine.costs)
    acc_cheap = np.mean(
        [wl.invoke(int(cheap), int(c), int(l), rng) == l for c, l in queries]
    )
    assert acc > acc_cheap + 0.02


def test_router_accuracy_tracks_xi_estimate(setup):
    wl, est, engine, router = setup
    queries, emb, lab = _queries(wl, 500)
    budget = float(np.quantile(engine.costs, 0.8)) * 3
    res = router.route_batch(queries, emb, budget)
    acc = (res.predictions == lab).mean()
    assert acc > 0.85


def test_wavefront_stops_early_on_consensus(setup):
    """Easy clusters should not invoke every selected arm."""
    wl, est, engine, router = setup
    queries, emb, lab = _queries(wl, 200)
    budget = float(engine.costs.sum())  # everything affordable
    res = router.route_batch(queries, emb, budget)
    n_used = np.array([len(a) for a in res.arms_used])
    planned = res.planned_costs
    assert (res.costs <= planned + 1e-12).all()
    assert n_used.mean() > 0


def test_scheduler_batches_and_routes(setup):
    wl, est, engine, router = setup
    queries, emb, lab = _queries(wl, 64)
    sched = BatchScheduler(router, max_batch=16, max_wait_s=0.0)
    budget = float(np.quantile(engine.costs, 0.6)) * 2
    for q, e in zip(queries, emb):
        sched.submit(Request(payload=q, embedding=e, budget=budget))
    total = 0
    while sched.ready():
        for group, res in sched.flush():
            total += len(group)
            assert (res.costs <= budget + 1e-12).all()
    assert total == 64
    assert sched.stats["batches"] == 4


def test_straggler_hedge_plan(setup):
    _, _, _, router = setup
    sched = BatchScheduler(router)
    plan = sched.mitigator.hedge_plan([3, 1, 5], slow_arm=1)
    assert plan == [3, 5, 1]
