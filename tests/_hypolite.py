"""Minimal hypothesis-compatible property-test engine (fallback).

The tier-1 container cannot install new packages, yet the property suites
must actually *run* — `scripts/ci.sh` fails the build if they skip, so the
old ``pytest.importorskip("hypothesis")`` path can no longer silently mask
them. This module implements the tiny subset of the hypothesis API those
suites use; when the real ``hypothesis`` is installed it is preferred
(richer example diversity, shrinking), and this file is never imported.

Supported surface:
  * ``@given(*strategies)`` over positional strategies
  * ``@settings(max_examples=..., deadline=...)`` (outermost decorator)
  * ``strategies.floats / integers / lists / booleans / sampled_from /
    tuples / just``

Draws are deterministic per test (rng seeded from the test's qualname)
with a light boundary bias so interval endpoints get exercised. A failing
example is re-raised with the drawn values in the message.
"""
from __future__ import annotations

import functools
import inspect
import zlib
from typing import Any, Sequence

import numpy as np

DEFAULT_MAX_EXAMPLES = 100


class SearchStrategy:
    def draw(self, rng: np.random.Generator) -> Any:
        raise NotImplementedError


class _Floats(SearchStrategy):
    def __init__(self, min_value: float, max_value: float):
        self.lo, self.hi = float(min_value), float(max_value)

    def draw(self, rng):
        r = rng.random()
        if r < 0.05:
            return self.lo
        if r < 0.10:
            return self.hi
        return float(self.lo + (self.hi - self.lo) * rng.random())


class _Integers(SearchStrategy):
    def __init__(self, min_value: int, max_value: int):
        self.lo, self.hi = int(min_value), int(max_value)

    def draw(self, rng):
        r = rng.random()
        if r < 0.05:
            return self.lo
        if r < 0.10:
            return self.hi
        return int(rng.integers(self.lo, self.hi + 1))


class _Lists(SearchStrategy):
    def __init__(self, elements: SearchStrategy, min_size=0, max_size=10):
        self.elements = elements
        self.min_size, self.max_size = int(min_size), int(max_size)

    def draw(self, rng):
        n = int(rng.integers(self.min_size, self.max_size + 1))
        return [self.elements.draw(rng) for _ in range(n)]


class _Booleans(SearchStrategy):
    def draw(self, rng):
        return bool(rng.integers(2))


class _SampledFrom(SearchStrategy):
    def __init__(self, elements: Sequence):
        self.elements = list(elements)

    def draw(self, rng):
        return self.elements[int(rng.integers(len(self.elements)))]


class _Tuples(SearchStrategy):
    def __init__(self, *strategies: SearchStrategy):
        self.strategies = strategies

    def draw(self, rng):
        return tuple(s.draw(rng) for s in self.strategies)


class _Just(SearchStrategy):
    def __init__(self, value):
        self.value = value

    def draw(self, rng):
        return self.value


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (the used subset)."""

    @staticmethod
    def floats(min_value: float, max_value: float) -> SearchStrategy:
        return _Floats(min_value, max_value)

    @staticmethod
    def integers(min_value: int, max_value: int) -> SearchStrategy:
        return _Integers(min_value, max_value)

    @staticmethod
    def lists(elements: SearchStrategy, min_size=0, max_size=10) -> SearchStrategy:
        return _Lists(elements, min_size=min_size, max_size=max_size)

    @staticmethod
    def booleans() -> SearchStrategy:
        return _Booleans()

    @staticmethod
    def sampled_from(elements: Sequence) -> SearchStrategy:
        return _SampledFrom(elements)

    @staticmethod
    def tuples(*strats: SearchStrategy) -> SearchStrategy:
        return _Tuples(*strats)

    @staticmethod
    def just(value) -> SearchStrategy:
        return _Just(value)


def given(*strats: SearchStrategy):
    """Run the wrapped test on ``max_examples`` deterministic draws."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_hypolite_settings", {})
            n = cfg.get("max_examples", DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(
                zlib.crc32(fn.__qualname__.encode("utf-8"))
            )
            for i in range(n):
                vals = tuple(s.draw(rng) for s in strats)
                try:
                    fn(*args, *vals, **kwargs)
                except Exception as exc:
                    raise AssertionError(
                        f"falsifying example ({i + 1}/{n}, hypolite engine): "
                        f"{fn.__name__}{vals!r}"
                    ) from exc

        # hide the drawn parameters from pytest's fixture resolution: the
        # wrapper itself takes no arguments (wraps() would otherwise expose
        # the wrapped signature via __wrapped__)
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        wrapper.is_hypothesis_test = True  # parity with the real engine
        return wrapper

    return deco


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    """Config decorator; only ``max_examples`` is meaningful here."""

    def deco(fn):
        fn._hypolite_settings = {"max_examples": int(max_examples)}
        return fn

    return deco
