"""Multi-device replica placement (repro/serving/replica.py, overlapped).

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the CI
device stage does) to exercise the real multi-device plane; on a plain
single-device process the multi-device cases skip and only the fallback
contracts run. The contracts pinned here:

* **Device assignment.** ``replica_devices(R)`` round-robins R replicas
  over the local devices; ``replica_mesh(R)`` is a 1-D ``("replica",)``
  mesh over ``min(R, devices)``. Both degrade to None/None-list on one
  device.
* **Overlapped is the multi-device default** — and it bit-matches both
  the fused single-dispatch placement and a plain BatchScheduler, per
  request, on a fault-free deterministic pool.
* **Fault-grid equivalence.** Per-launch ``fault_row_offset`` makes the
  overlapped placement draw the fused dispatch's fault grid cell for
  cell: fused and overlapped streams bit-match *under an active
  FaultPolicy* too.
* **Compile budgets.** ``prewarm_compile`` warms every (batch bucket,
  wave bucket) pair on every distinct worker device; a subsequent
  overlapped stream — homogeneous or split across budget tiers — causes
  zero timed wave-program compiles.
* **Graceful single-device fallback.** ``placement="overlapped"`` on one
  device still completes correctly (no pins, no overlap), and the
  default placement picks fused there.
"""
import numpy as np
import pytest

import jax

from repro.distributed.sharding import replica_devices, replica_mesh
from repro.analysis import CompileSentinel
from repro.serving import (
    BatchScheduler,
    FaultPolicy,
    ReplicaSet,
)
from repro.serving import router as router_mod

from tests.test_replica import _make_pool, _budget

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4",
)


# ---------------------------------------------------------------------------
# Device assignment
# ---------------------------------------------------------------------------


@multi_device
def test_replica_devices_round_robin():
    devs = jax.devices()
    got = replica_devices(len(devs) + 2)
    assert got[: len(devs)] == devs
    assert got[len(devs)] == devs[0] and got[len(devs) + 1] == devs[1]
    mesh = replica_mesh(len(devs))
    assert mesh is not None and mesh.axis_names == ("replica",)
    assert mesh.devices.size == len(devs)
    # R smaller than the device count only spans R devices
    m2 = replica_mesh(2)
    assert m2.devices.size == 2


@multi_device
def test_workers_are_pinned_one_router_per_device():
    _, router, _, _ = _make_pool()
    R = len(jax.devices())
    rset = ReplicaSet(router, replicas=R, max_batch=16, max_wait_s=0.0)
    assert rset.placement == "overlapped"
    assert rset.device_count == R
    pins = [w.router.device for w in rset.workers]
    assert pins == jax.devices()[:R]
    # distinct router clones — a shared router object would serialise the
    # per-device dispatches through one pin
    assert len({id(w.router) for w in rset.workers}) == R
    # a later non-overlapped set on the same (reused) template router
    # clears the stale pin
    rf = ReplicaSet(router, replicas=R, max_batch=16, max_wait_s=0.0,
                    placement="fused")
    assert all(w.router.device is None for w in rf.workers)


# ---------------------------------------------------------------------------
# Bit-identity: overlapped == fused == plain scheduler (fault-free)
# ---------------------------------------------------------------------------


@multi_device
def test_overlapped_r4_bitmatches_fused_and_baseline():
    engine_a, router_a, qemb, _ = _make_pool()
    engine_b, router_b, _, _ = _make_pool()
    engine_c, router_c, _, _ = _make_pool()
    budget = _budget(engine_a)
    B = qemb.shape[0]

    ro = ReplicaSet(router_a, replicas=4, max_batch=16, max_wait_s=0.0)
    assert ro.placement == "overlapped"
    blk_o = ro.submit_many(np.arange(B), qemb, budget)
    ro.drain()

    rf = ReplicaSet(router_b, replicas=4, max_batch=16, max_wait_s=0.0,
                    placement="fused")
    blk_f = rf.submit_many(np.arange(B), qemb, budget)
    rf.drain()

    base = BatchScheduler(router_c, max_batch=B, max_wait_s=0.0)
    ref = base.submit_many(np.arange(B), qemb, budget)
    base.drain()

    for blk in (blk_o, blk_f):
        np.testing.assert_array_equal(blk.predictions, ref.predictions)
        np.testing.assert_array_equal(blk.costs, ref.costs)
        np.testing.assert_array_equal(blk.stop_waves, ref.stop_waves)
    np.testing.assert_array_equal(ro.arm_query_totals, base.arm_query_totals)
    st = ro.stats
    assert st["replica_overlapped"] >= 1
    assert st["replica_overlapped_rows"] == B
    assert st["replica_fused"] == 0
    assert st["replica_devices"] == min(4, len(jax.devices()))
    assert rf.stats["replica_fused"] >= 1


@multi_device
def test_overlapped_r1_bitmatches_plain_scheduler():
    """The R=1 anchor holds with an explicit overlapped placement: one
    worker, offset 0, dispatch-per-group — the standalone cadence."""
    engine_a, router_a, qemb, _ = _make_pool()
    engine_b, router_b, _, _ = _make_pool()
    budget = _budget(engine_a)
    B = qemb.shape[0]
    rset = ReplicaSet(router_a, replicas=1, max_batch=16, max_wait_s=0.0,
                      placement="overlapped")
    blk = rset.submit_many(np.arange(B), qemb, budget)
    rset.drain()
    base = BatchScheduler(router_b, max_batch=16, max_wait_s=0.0)
    ref = base.submit_many(np.arange(B), qemb, budget)
    base.drain()
    np.testing.assert_array_equal(blk.predictions, ref.predictions)
    np.testing.assert_array_equal(blk.costs, ref.costs)
    np.testing.assert_array_equal(blk.stop_waves, ref.stop_waves)


# ---------------------------------------------------------------------------
# Fault-grid equivalence through fault_row_offset
# ---------------------------------------------------------------------------


def _run_with_faults(placement, seed=7):
    engine, router, qemb, _ = _make_pool()
    budget = _budget(engine)
    B = qemb.shape[0]
    policy = FaultPolicy(len(engine.arms), 4, seed=seed)
    hot = int(np.argmin(engine.costs))
    policy.set_arm(hot, timeout=0.4, error=0.3)
    engine.fault_policy = policy
    try:
        rset = ReplicaSet(router, replicas=3, max_batch=16, max_wait_s=0.0,
                          placement=placement)
        blk = rset.submit_many(np.arange(B), qemb, budget)
        rset.drain()
    finally:
        engine.fault_policy = None
    return blk, rset.stats


@multi_device
@pytest.mark.parametrize("seed", [7, 13])
def test_fault_grid_overlapped_bitmatches_fused(seed):
    """Same FaultPolicy seed, same admission wave: the overlapped R=3
    stream draws the identical fault grid as the fused one (per-launch
    row offsets reproduce the concatenation positions), so every output
    — predictions, costs, stop waves, degrade modes — bit-matches."""
    blk_o, st_o = _run_with_faults("overlapped", seed=seed)
    blk_f, st_f = _run_with_faults("fused", seed=seed)
    np.testing.assert_array_equal(blk_o.predictions, blk_f.predictions)
    np.testing.assert_array_equal(blk_o.costs, blk_f.costs)
    np.testing.assert_array_equal(blk_o.stop_waves, blk_f.stop_waves)
    np.testing.assert_array_equal(blk_o.modes, blk_f.modes)
    assert st_o.get("degradation_failures") == st_f.get("degradation_failures")


# ---------------------------------------------------------------------------
# Compile budgets on the device plane
# ---------------------------------------------------------------------------


@multi_device
def test_overlapped_stream_zero_recompiles_after_prewarm():
    """prewarm_compile walks every distinct worker device and warms all
    ragged (B, T) buckets there — a homogeneous stream then a budget-tier
    split stream both run with zero timed wave compiles."""
    engine, router, qemb, _ = _make_pool()
    budget = _budget(engine)
    rset = ReplicaSet(router, replicas=4, max_batch=16, max_wait_s=0.0)
    assert rset.placement == "overlapped"
    rset.prewarm(budgets=[budget])
    rset.prewarm_compile()
    sentinel = CompileSentinel({"wave": router_mod._wave_scan})
    sentinel.snapshot()
    for _ in range(3):
        blk = rset.submit_many(np.arange(qemb.shape[0]), qemb, budget)
        rset.drain()
        assert blk.done()
    sentinel.assert_no_new_compiles(
        detail="overlapped R=4 homogeneous stream after prewarm_compile"
    )

    rng = np.random.default_rng(11)
    levels = np.quantile(engine.costs, [0.4, 0.8]) * 2.5
    budgets = rng.choice(levels, size=qemb.shape[0])
    rset2 = ReplicaSet(router, replicas=4, max_batch=16, max_wait_s=0.0)
    rset2.prewarm(budgets=[float(v) for v in levels])
    rset2.prewarm_compile()
    sentinel.snapshot()
    blk = rset2.submit_many(np.arange(qemb.shape[0]), qemb, budgets)
    rset2.drain()
    assert blk.done()
    sentinel.assert_no_new_compiles(
        detail="overlapped R=4 split-budget stream after prewarm_compile"
    )


# ---------------------------------------------------------------------------
# Single-device fallback (runs everywhere, including plain tier-1)
# ---------------------------------------------------------------------------


def test_single_device_defaults_and_overlapped_fallback():
    engine_a, router_a, qemb, _ = _make_pool()
    engine_b, router_b, _, _ = _make_pool()
    budget = _budget(engine_a)
    B = qemb.shape[0]
    single = len(jax.devices()) == 1
    if single:
        assert replica_devices(3) == [None, None, None]
        assert replica_mesh(3) is None

    # explicit overlapped on however many devices exist: completes and
    # bit-matches the baseline (on one device the pins are None and the
    # dispatches simply serialise)
    rset = ReplicaSet(router_a, replicas=4, max_batch=16, max_wait_s=0.0,
                      placement="overlapped")
    if single:
        assert all(w.router.device is None for w in rset.workers)
        assert rset.device_count == 1
    blk = rset.submit_many(np.arange(B), qemb, budget)
    rset.drain()
    base = BatchScheduler(router_b, max_batch=B, max_wait_s=0.0)
    ref = base.submit_many(np.arange(B), qemb, budget)
    base.drain()
    np.testing.assert_array_equal(blk.predictions, ref.predictions)
    np.testing.assert_array_equal(blk.costs, ref.costs)

    # default placement: fused on one device, overlapped on several
    r2 = ReplicaSet(router_a, replicas=4, max_batch=16, max_wait_s=0.0)
    assert r2.placement == ("fused" if single else "overlapped")
