"""thriftlint: the linter's own test suite.

Three layers:

* **fixtures** — each rule pass fires exactly on the seeded violations in
  ``tests/lint_fixtures/`` (expected locations derived from the inline
  ``FIRES: <rule>`` markers) and nowhere else;
* **real tree** — the committed ``src/repro`` baseline is zero findings,
  and the walker resolves the entry points the rules depend on;
* **runtime sentinels** — ``CompileSentinel`` counts real XLA
  compilations and the tracer-leak guard turns leaks into errors.
"""
import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (
    ALL_RULES,
    CompileSentinel,
    compile_cache_size,
    run_lint,
    tracer_leak_guard,
)
from repro.analysis.findings import (
    BAD_SUPPRESSION,
    apply_suppressions,
    parse_suppressions,
)
from repro.analysis.walker import Project

REPO = Path(__file__).resolve().parent.parent
FIXTURES = Path(__file__).resolve().parent / "lint_fixtures"


def _expected_locations(rule: str) -> set[tuple[str, int]]:
    """(path, line) pairs carrying a ``FIRES: <rule>`` marker."""
    out = set()
    for path in (FIXTURES / "badrepro").rglob("*.py"):
        rel = path.relative_to(FIXTURES).as_posix()
        for lineno, line in enumerate(
            path.read_text().splitlines(), start=1
        ):
            if f"FIRES: {rule}" in line:
                out.add((rel, lineno))
    return out


class TestRulesFireOnFixtures:
    @pytest.mark.parametrize("rule", sorted(ALL_RULES))
    def test_rule_fires_exactly_on_seeded_violations(self, rule):
        report = run_lint(
            src_root=FIXTURES, package="badrepro", rules=(rule,)
        )
        expected = _expected_locations(rule)
        assert expected, f"fixture tree seeds no {rule} violations"
        actual = {(f.path, f.line) for f in report.findings}
        assert actual == expected
        assert all(f.rule == rule for f in report.findings)

    def test_all_rules_marker_census(self):
        """Every badrepro finding is a marked line and vice versa."""
        report = run_lint(src_root=FIXTURES, package="badrepro")
        expected = set()
        for rule in ALL_RULES:
            expected |= _expected_locations(rule)
        assert {(f.path, f.line) for f in report.findings} == expected


class TestRealTreeIsClean:
    @pytest.mark.parametrize("rule", sorted(ALL_RULES))
    def test_rule_silent_on_real_tree(self, rule):
        report = run_lint(src_root=REPO / "src", rules=(rule,))
        assert [f.format() for f in report.findings] == []

    def test_full_run_is_clean_and_suppressions_are_reasoned(self):
        report = run_lint(src_root=REPO / "src")
        assert report.ok, [f.format() for f in report.findings]
        # every committed suppression carries its justification
        assert all(s.has_reason for s in report.suppressions)


class TestSuppressionMachinery:
    def test_reasoned_reasonless_and_bare(self):
        report = run_lint(src_root=FIXTURES, package="suppdemo")
        by_rule = report.by_rule()
        # the reason-less comment is itself a finding...
        assert len(by_rule[BAD_SUPPRESSION]) == 1
        # ...and does NOT silence the violation on its line; the bare
        # violation also survives
        assert len(by_rule["f64-reduction"]) == 2
        # the reasoned suppression silenced exactly one finding
        assert len(report.suppressed) == 1
        assert report.suppressed[0].rule == "f64-reduction"

    def test_docstring_spelling_is_not_a_suppression(self):
        text = '"""docs say # thriftlint: ignore[jit-purity] reason"""\nx = 1\n'
        assert parse_suppressions("m.py", text) == []

    def test_bad_suppression_cannot_be_suppressed(self):
        text = "x = 1  # thriftlint: ignore[bad-suppression]\n"
        sup = parse_suppressions("m.py", text)
        surviving, suppressed = apply_suppressions([], sup)
        assert [f.rule for f in surviving] == [BAD_SUPPRESSION]
        assert not suppressed


class TestWalker:
    @pytest.fixture(scope="class")
    def project(self):
        return Project(REPO / "src")

    def test_finds_the_declared_entry_points(self, project):
        entries = {e.fn.qualname for e in project.jit_entries if e.fn}
        assert {"_wave_scan_core", "_sur_greedy_scan_core",
                "xi_from_responses", "sample_pool_responses"} <= entries

    def test_wrapper_assignment_idiom_resolves(self, project):
        # mc.py: `xi_from_responses_grouped = partial(jax.jit, ...)(core)`
        symbols = project.jitted_symbols()
        assert "xi_from_responses_grouped" in symbols
        assert symbols["xi_from_responses_grouped"].fn.qualname == (
            "_masked_xi_core"
        )
        assert "num_classes" in symbols[
            "xi_from_responses_grouped"
        ].static_argnames

    def test_nested_scan_bodies_are_reachable(self, project):
        names = {f.qualname for f in project.reachable}
        assert "_sur_greedy_scan_core.<locals>.body" in names
        assert "_sur_greedy_scan_core.<locals>.cond" in names

    def test_pallas_kernels_are_roots(self, project):
        assert len(project.pallas_sites) >= 5
        assert all(k in project.reachable for k in project.kernels)


class TestCLI:
    def test_zero_findings_zero_exit(self):
        out = subprocess.run(
            [sys.executable, str(REPO / "scripts" / "lint.py"),
             "--format=json"],
            capture_output=True, text=True, cwd=REPO,
        )
        assert out.returncode == 0, out.stdout + out.stderr
        report = json.loads(out.stdout)
        assert report["ok"] and report["findings"] == []

    def test_rule_filter_and_listing(self):
        out = subprocess.run(
            [sys.executable, str(REPO / "scripts" / "lint.py"),
             "--list-rules"],
            capture_output=True, text=True, cwd=REPO,
        )
        assert out.returncode == 0
        assert set(out.stdout.split()) == set(ALL_RULES)

    def test_nonzero_exit_on_findings(self):
        out = subprocess.run(
            [sys.executable, str(REPO / "scripts" / "lint.py"),
             "--src", str(FIXTURES), "--package", "badrepro"],
            capture_output=True, text=True, cwd=REPO,
        )
        assert out.returncode == 1
        assert "jit-purity" in out.stdout


class TestCompileSentinel:
    def test_counts_real_compilations(self):
        @jax.jit
        def double(x):
            return x * 2

        sentinel = CompileSentinel({"d": double})
        double(jnp.ones(3))
        assert sentinel.compiles("d") == 1
        double(jnp.ones(3) * 5.0)        # same shape: cache hit
        assert sentinel.compiles("d") == 1
        double(jnp.ones(4))              # new shape: one more program
        assert sentinel.compiles("d") == 2
        with pytest.raises(AssertionError, match="recompilation"):
            sentinel.assert_no_new_compiles()
        sentinel.snapshot()
        sentinel.assert_no_new_compiles()
        sentinel.assert_within({"d": 0})
        double(jnp.ones(5))
        with pytest.raises(AssertionError, match="budget"):
            sentinel.assert_within({"d": 0})

    def test_rejects_plain_functions(self):
        with pytest.raises(TypeError, match="_cache_size"):
            compile_cache_size(lambda x: x)
        with pytest.raises(TypeError):
            CompileSentinel({"plain": lambda x: x})


class TestTracerGuard:
    def test_leak_raises(self):
        leaked = []

        def leaky(x):
            leaked.append(x)     # smuggle the tracer into host state
            return x * 2

        with pytest.raises(Exception, match="[Ll]eak"):
            with tracer_leak_guard():
                jax.jit(leaky)(jnp.ones(3))

    def test_clean_trace_passes(self):
        with tracer_leak_guard():
            assert float(jax.jit(lambda x: x * 2)(jnp.ones(()))) == 2.0
