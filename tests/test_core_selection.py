"""Paper-faithful behavior of the core selection algorithms."""
import itertools

import jax
import numpy as np
import pytest

from repro.core import (
    McXiEstimator,
    adaptive_invoke,
    aggregate_predict,
    gamma,
    greedy,
    gamma_value_batch,
    sur_greedy,
    theta_for,
    xi_exact,
    xi_pair,
)


def brute_force_oes(p, b, budget, K):
    """Exact optimum by enumerating all feasible subsets (small L only)."""
    L = len(p)
    best, best_set = 0.0, ()
    for r in range(L + 1):
        for S in itertools.combinations(range(L), r):
            if sum(b[i] for i in S) <= budget + 1e-12:
                v = xi_exact(np.asarray(p)[list(S)], K, p_all=p) if S else 1.0 / K
                if v > best:
                    best, best_set = v, S
    return best, best_set


class TestCorrectnessProbability:
    def test_prop2_pair_equals_max(self):
        rng = np.random.default_rng(0)
        for _ in range(20):
            p = rng.uniform(0.35, 0.98, 2)
            K = int(rng.integers(2, 8))
            assert xi_exact(p, K) == pytest.approx(max(p), abs=1e-9)
            assert xi_pair(*p) == max(p)

    def test_lemma1_monotone_in_probs(self):
        rng = np.random.default_rng(1)
        for _ in range(10):
            m, K = int(rng.integers(1, 5)), int(rng.integers(2, 5))
            p = rng.uniform(0.3, 0.9, m)
            hi = np.clip(p + rng.uniform(0, 0.08, m), 0, 0.99)
            assert xi_exact(hi, K) >= xi_exact(p, K) - 1e-9

    def test_lemma1_monotone_in_set(self):
        rng = np.random.default_rng(2)
        for _ in range(10):
            m, K = int(rng.integers(2, 5)), int(rng.integers(2, 5))
            p = rng.uniform(0.3, 0.9, m)
            assert xi_exact(p, K, p_all=p) >= xi_exact(p[:-1], K, p_all=p) - 1e-9

    def test_lemma2_non_submodular_counterexample(self):
        # p1 > p2, p1 > p3, but w2*w3 > w1 -> adding l3 to {l1,l2} gains,
        # while adding it to {l1} gains nothing (Prop. 2).
        K = 2
        p1, p2, p3 = 0.90, 0.85, 0.85
        S, T = [p1], [p1, p2]
        gain_S = xi_exact(np.array(S + [p3]), K) - xi_exact(np.array(S), K)
        gain_T = xi_exact(np.array(T + [p3]), K) - xi_exact(np.array(T), K)
        assert gain_T > gain_S + 1e-6, "submodularity should be violated"

    def test_lemma3_gamma_upper_bounds_xi(self):
        rng = np.random.default_rng(3)
        for _ in range(30):
            m, K = int(rng.integers(1, 6)), int(rng.integers(2, 6))
            p = rng.uniform(0.2, 0.95, m)
            assert gamma(p) >= xi_exact(p, K) - 1e-9

    def test_gamma_submodular(self):
        rng = np.random.default_rng(4)
        for _ in range(30):
            probs = rng.uniform(0.1, 0.9, 6)
            s1 = [0, 1]
            s2 = [0, 1, 2, 3]
            l = 5
            g1 = gamma(probs[s1 + [l]]) - gamma(probs[s1])
            g2 = gamma(probs[s2 + [l]]) - gamma(probs[s2])
            assert g1 >= g2 - 1e-12

    def test_xi_empty_set(self):
        est = McXiEstimator(jax.random.key(0), np.array([0.9, 0.8]), 4, 20000)
        assert est.xi([]) == pytest.approx(0.25, abs=0.02)


class TestMonteCarlo:
    def test_theta_formula(self):
        th = theta_for(0.1, 0.01, 0.9, 12)
        expect = (8 + 2 * 0.1) / (0.1 ** 2 * 0.9) * np.log(2 * 144 / 0.01)
        assert th == int(np.ceil(expect))

    @pytest.mark.parametrize("K", [2, 3, 7])
    def test_mc_matches_exact(self, K):
        p = np.array([0.9, 0.75, 0.6, 0.85])
        est = McXiEstimator(jax.random.key(1), p, K, theta=150_000)
        assert est.xi(range(4)) == pytest.approx(xi_exact(p, K), abs=0.006)

    def test_lemma4_concentration(self):
        """|xi - xi_hat| <= eps*p*/2 holds across keys with theta from Alg 3."""
        p = np.array([0.9, 0.8, 0.7])
        K, eps = 3, 0.2
        theta = theta_for(eps, 0.01, 0.9, 3)
        exact = xi_exact(p, K)
        bad = 0
        for s in range(10):
            est = McXiEstimator(jax.random.key(s), p, K, theta)
            if abs(est.xi(range(3)) - exact) > eps * 0.9 / 2:
                bad += 1
        assert bad == 0


class TestGreedy:
    def test_vanilla_greedy_can_be_arbitrarily_bad(self):
        """Paper Section 4.2 example: ratio-greedy picks the cheap weak arm."""
        p = np.array([0.9, 0.2])
        b = np.array([1.0, 0.001])
        budget = 1.0
        chosen, _ = greedy(p, b, budget, gamma_value_batch(p), empty_value=0.0)
        assert chosen[0] == 1  # myopically picks the cheap arm first

    def test_sur_greedy_beats_vanilla_trap(self):
        p = np.array([0.9, 0.2])
        b = np.array([1.0, 0.001])
        res = sur_greedy(p, b, 1.0, 2, jax.random.key(0), theta=20_000)
        assert 0 in list(res.chosen)  # best single arm rescued via l*
        assert res.xi_est >= 0.85

    def test_budget_respected(self):
        rng = np.random.default_rng(5)
        for s in range(5):
            L = 6
            p = rng.uniform(0.4, 0.95, L)
            b = rng.uniform(0.1, 1.0, L)
            budget = float(rng.uniform(0.3, 2.0))
            res = sur_greedy(p, b, budget, 3, jax.random.key(s), theta=5_000)
            assert res.cost <= budget + 1e-9

    def test_theorem3_bound_holds_vs_bruteforce(self):
        rng = np.random.default_rng(6)
        for s in range(5):
            L, K = 5, 3
            p = rng.uniform(0.4, 0.95, L)
            b = rng.uniform(0.1, 0.6, L)
            budget = 1.0
            res = sur_greedy(p, b, budget, K, jax.random.key(s), theta=40_000)
            opt, _ = brute_force_oes(p, b, budget, K)
            xi_star = xi_exact(p[res.chosen], K, p_all=p) if len(res.chosen) else 1 / K
            bound = res.approx_ratio_bound * (1 - 1 / np.sqrt(np.e)) * opt
            assert xi_star >= bound - 0.02  # eps-slack for MC noise


class TestAdaptive:
    def _roll(self, p, K, truth, seed):
        r = np.random.default_rng(seed)

        def invoke(i):
            if r.random() < p[i]:
                return truth
            return int((truth + 1 + r.integers(K - 1)) % K)

        return invoke

    def test_prop4_prediction_equality(self):
        p = np.array([0.9, 0.8, 0.7, 0.6, 0.85, 0.75])
        b = np.ones(6) * 0.2
        K = 4
        res = sur_greedy(p, b, 1.0, K, jax.random.key(0), theta=10_000)
        order = sorted(res.chosen, key=lambda i: -p[i])
        for s in range(200):
            inv = adaptive_invoke(list(res.chosen), p, K, self._roll(p, K, 2, s), costs=b)
            r2 = np.random.default_rng(s)
            full = []
            for i in order:
                full.append(2 if r2.random() < p[i] else int((3 + r2.integers(K - 1)) % K))
            full_pred = aggregate_predict(np.asarray(full), p[order], K, p_all=p)
            assert inv.prediction == full_pred

    def test_adaptive_cost_never_exceeds_planned(self):
        p = np.array([0.9, 0.8, 0.7, 0.6])
        b = np.array([0.4, 0.3, 0.2, 0.1])
        K = 3
        for s in range(50):
            inv = adaptive_invoke([0, 1, 2, 3], p, K, self._roll(p, K, 1, s), costs=b)
            assert inv.cost <= inv.planned_cost + 1e-12

    def test_adaptive_saves_cost_on_easy_queries(self):
        p = np.array([0.97, 0.96, 0.95, 0.94, 0.93])
        b = np.ones(5)
        savings = []
        for s in range(100):
            inv = adaptive_invoke([0, 1, 2, 3, 4], p, 2, self._roll(p, 2, 0, s), costs=b)
            savings.append(1 - inv.cost / inv.planned_cost)
        assert np.mean(savings) > 0.2  # strong agreement stops early
