"""Batched planner (`sur_greedy_many` / `select_many` / `plan_many`):
bitwise equivalence with the serial plane under shared CRN seeds.

The contract under test is the PR 5 tentpole: one jitted program planning G
(p-vector, budget) groups returns exactly the chosen sets, orders, values
and spend the serial per-group `sur_greedy` produces — across shapes,
ragged affordability, padding buckets and group permutations.
"""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # container fallback
    from _hypolite import given, settings, strategies as st

from repro.core import (
    GroupedXiEstimator,
    ThriftLLM,
    sample_pool_responses,
    sample_pool_responses_grouped,
    sur_greedy,
    sur_greedy_many,
)
from repro.core.types import SelectionResult


def _assert_same(s: SelectionResult, m: SelectionResult):
    """Bitwise equality of everything the planner derives."""
    assert np.array_equal(s.chosen, m.chosen)
    assert s.xi_est == m.xi_est and s.cost == m.cost and s.budget == m.budget
    assert (s.s1 is None) == (m.s1 is None)
    if s.s1 is not None:
        assert np.array_equal(s.s1, m.s1) and np.array_equal(s.s2, m.s2)
        assert s.l_star == m.l_star
        assert s.xi_s1 == m.xi_s1 and s.xi_s2 == m.xi_s2
        assert s.p_star == m.p_star and s.gamma_s2 == m.gamma_s2


def _case(seed, G, L, K, budget_lo, budget_hi):
    rng = np.random.default_rng(seed)
    ps = rng.uniform(0.2, 0.98, (G, L))
    b = rng.uniform(0.05, 1.0, L)
    budgets = rng.uniform(budget_lo, budget_hi, G)
    thetas = rng.integers(120, 700, G)
    return ps, b, budgets, thetas


class TestBatchedEqualsSerial:
    @pytest.mark.parametrize(
        "seed,G,L,K",
        [
            (0, 1, 4, 2),      # single group == the serial plane
            (1, 3, 6, 3),
            (2, 8, 12, 4),     # a full group bucket
            (3, 9, 12, 4),     # ragged G (padded to the next bucket)
            (4, 5, 8, 7),
            (5, 4, 6, 19),     # big-K histogram fallback path
        ],
    )
    def test_equivalence_grid(self, seed, G, L, K):
        ps, b, budgets, thetas = _case(seed, G, L, K, 0.3, 2.5)
        key = jax.random.key(42)
        serial = [
            sur_greedy(ps[g], b, float(budgets[g]), K, key, int(thetas[g]))
            for g in range(G)
        ]
        batched = sur_greedy_many(ps, b, budgets, K, key, thetas)
        for s, m in zip(serial, batched):
            _assert_same(s, m)

    def test_ragged_affordability(self):
        """Groups whose budget affords nothing reproduce the serial early
        return, and their presence does not perturb the live groups."""
        ps, b, budgets, thetas = _case(7, 6, 8, 4, 0.3, 1.5)
        budgets[1] = 0.0                     # affords nothing
        budgets[4] = float(b.min()) * 0.5    # still nothing
        key = jax.random.key(3)
        serial = [
            sur_greedy(ps[g], b, float(budgets[g]), 4, key, int(thetas[g]))
            for g in range(6)
        ]
        batched = sur_greedy_many(ps, b, budgets, 4, key, thetas)
        for s, m in zip(serial, batched):
            _assert_same(s, m)
        assert batched[1].chosen.size == 0 and batched[1].s1 is None
        assert batched[1].xi_est == 0.25

    def test_shared_draws_are_prefix_stable(self):
        """Group g's rows of the grouped sample tensor are bitwise the
        serial draws for its own theta — the CRN sharing contract."""
        key = jax.random.key(11)
        ps = np.random.default_rng(0).uniform(0.2, 0.95, (3, 6)).astype(np.float32)
        grouped = np.asarray(
            sample_pool_responses_grouped(key, ps, 5, 512)
        )
        for g, t in enumerate((17, 256, 512)):
            one = np.asarray(sample_pool_responses(key, ps[g], 5, t))
            assert np.array_equal(one, grouped[g, :t])

    def test_grouped_estimator_padding_invariance(self):
        """xi of the same masks is bitwise identical whether a group is
        evaluated alone or stacked with larger-theta groups (padding and
        batching cannot perturb the exact credit sums)."""
        rng = np.random.default_rng(5)
        ps = rng.uniform(0.3, 0.95, (3, 6))
        thetas = np.asarray([150, 400, 611])
        key = jax.random.key(2)
        est = GroupedXiEstimator(key, ps, 4, thetas)
        masks = (rng.random((3, 5, 6)) < 0.5).astype(np.float32)
        stacked = est(masks)
        for g in range(3):
            alone = GroupedXiEstimator(key, ps[g][None], 4, thetas[g:g + 1])
            np.testing.assert_array_equal(alone(masks[g][None])[0], stacked[g])


class TestSelectMany:
    def test_select_many_matches_select_and_shares_cache(self):
        ps, b, budgets, _ = _case(9, 5, 8, 4, 0.4, 2.0)
        sel_a = ThriftLLM(b, eps=0.3, seed=1)
        sel_b = ThriftLLM(b, eps=0.3, seed=1)
        serial = [sel_a.select(ps[g], 4, float(budgets[g])) for g in range(5)]
        batched = sel_b.select_many(ps, 4, budgets)
        for s, m in zip(serial, batched):
            _assert_same(s, m)
        # the batched results are memoized under the serial keys: a serial
        # select afterwards is a pure cache hit returning the same object
        for g in range(5):
            assert sel_b.select(ps[g], 4, float(budgets[g])) is batched[g]

    def test_select_many_duplicate_pairs_build_once(self):
        ps, b, budgets, _ = _case(10, 2, 6, 3, 0.5, 1.5)
        dup = np.concatenate([ps, ps[:1]])
        dbud = np.concatenate([budgets, budgets[:1]])
        sel = ThriftLLM(b, eps=0.3)
        out = sel.select_many(dup, 3, dbud)
        assert out[0] is out[2]               # same memo entry, one build


class TestCompileBudget:
    """CompileSentinel: `_sur_greedy_scan` is compiled per (G-bucket, L,
    theta-bucket, K) — steady replanning traffic must stay in cache."""

    def test_sur_greedy_many_content_change_does_not_recompile(self):
        from repro.analysis import CompileSentinel, compile_cache_size
        from repro.core import selection as selection_mod

        G, L, K = 8, 12, 4
        thetas = np.full(G, 300)        # pin the theta bucket across runs
        b = np.random.default_rng(0).uniform(0.05, 1.0, L)
        key = jax.random.key(42)
        sentinel = CompileSentinel(
            {"plan": selection_mod._sur_greedy_scan}
        )
        rng = np.random.default_rng(1)
        sur_greedy_many(
            rng.uniform(0.2, 0.98, (G, L)), b, rng.uniform(0.3, 2.5, G),
            K, key, thetas,
        )
        # in cache (earlier tests may have warmed this bucket already, so
        # assert the absolute population, not the since-construction delta)
        assert compile_cache_size(selection_mod._sur_greedy_scan) >= 1
        sentinel.snapshot()
        for s in (2, 3, 4):
            rng = np.random.default_rng(s)
            sur_greedy_many(
                rng.uniform(0.2, 0.98, (G, L)), b,
                rng.uniform(0.3, 2.5, G), K, key, thetas,
            )
        sentinel.assert_no_new_compiles(
            detail="sur_greedy_many content change within one "
            "(G, theta) bucket"
        )

    def test_ragged_groups_share_the_warm_bucket(self):
        from repro.analysis import CompileSentinel
        from repro.core import selection as selection_mod

        L, K = 12, 4
        b = np.random.default_rng(0).uniform(0.05, 1.0, L)
        key = jax.random.key(7)
        sentinel = CompileSentinel(
            {"plan": selection_mod._sur_greedy_scan}
        )
        rng = np.random.default_rng(9)
        sur_greedy_many(
            rng.uniform(0.2, 0.98, (8, L)), b, rng.uniform(0.3, 2.5, 8),
            K, key, np.full(8, 300),
        )
        sentinel.snapshot()
        # ragged G in (5, 6, 7) pads to the same G=8 bucket: cache hits only
        for G in (5, 6, 7):
            rng = np.random.default_rng(G)
            sur_greedy_many(
                rng.uniform(0.2, 0.98, (G, L)), b,
                rng.uniform(0.3, 2.5, G), K, key, np.full(G, 300),
            )
        sentinel.assert_no_new_compiles(
            detail="ragged G padded into the warm G-bucket"
        )


class TestFusedGammaPlane:
    """PR 10: the greedy-on-gamma / l* / candidate-scoring stages moved
    into the device program — the fused plane must stay bitwise the serial
    one everywhere, including exact gamma ties and padding."""

    @pytest.mark.parametrize(
        "seed,G,L,budget_lo,budget_hi",
        [
            (20, 1, 4, 0.3, 1.0),
            (21, 3, 8, 0.2, 0.8),    # tight budgets: ragged affordability
            (22, 8, 6, 0.5, 2.5),
            (23, 9, 10, 0.3, 3.5),   # ragged G, generous budgets
            (24, 5, 12, 0.1, 0.6),
        ],
    )
    def test_equivalence_grid(self, seed, G, L, budget_lo, budget_hi):
        K = 4
        ps, b, budgets, thetas = _case(seed, G, L, K, budget_lo, budget_hi)
        key = jax.random.key(5)
        serial = [
            sur_greedy(ps[g], b, float(budgets[g]), K, key, int(thetas[g]))
            for g in range(G)
        ]
        batched = sur_greedy_many(ps, b, budgets, K, key, thetas)
        for s, m in zip(serial, batched):
            _assert_same(s, m)

    def test_exact_gamma_ties(self):
        """Duplicated (p, b) columns make every gamma-plane round an exact
        ratio tie; the device argmax must reproduce the serial p/b-then-
        first-index tie-break bit for bit."""
        rng = np.random.default_rng(30)
        G, half = 4, 5
        ps_half = rng.uniform(0.3, 0.9, (G, half))
        ps = np.concatenate([ps_half, ps_half], axis=1)
        b_half = rng.uniform(0.1, 0.8, half)
        b = np.concatenate([b_half, b_half])
        budgets = rng.uniform(0.5, 3.0, G)
        thetas = rng.integers(150, 500, G)
        key = jax.random.key(8)
        serial = [
            sur_greedy(ps[g], b, float(budgets[g]), 3, key, int(thetas[g]))
            for g in range(G)
        ]
        batched = sur_greedy_many(ps, b, budgets, 3, key, thetas)
        for s, m in zip(serial, batched):
            _assert_same(s, m)

    def test_nothing_affordable_groups_are_inert(self):
        """Zero-budget groups take the serial early return and do not
        perturb the live groups sharing their dispatch."""
        ps, b, budgets, thetas = _case(31, 5, 7, 4, 0.5, 2.0)
        budgets[0] = 0.0
        budgets[3] = float(b.min()) * 0.25
        key = jax.random.key(9)
        serial = [
            sur_greedy(ps[g], b, float(budgets[g]), 4, key, int(thetas[g]))
            for g in range(5)
        ]
        batched = sur_greedy_many(ps, b, budgets, 4, key, thetas)
        for s, m in zip(serial, batched):
            _assert_same(s, m)
        assert batched[0].s1 is None and batched[3].s1 is None

    def test_padded_bucket_invariance(self):
        """The same groups planned under group_bucket=8 (G=5 pads to 8)
        and group_bucket=64 (pads to 64) are bitwise identical — padded
        rows are inert."""
        ps, b, budgets, thetas = _case(32, 5, 9, 4, 0.3, 2.0)
        key = jax.random.key(12)
        small = sur_greedy_many(
            ps, b, budgets, 4, key, thetas, group_bucket=8
        )
        large = sur_greedy_many(
            ps, b, budgets, 4, key, thetas, group_bucket=64
        )
        for s, m in zip(small, large):
            _assert_same(s, m)

    def test_hostgamma_baseline_equivalence(self):
        """The retained PR 9 plane (host gamma/l* loop + separate final_xi
        dispatch) and the fused plane agree bitwise — the bench baseline
        measures speed, not drift."""
        from repro.core.selection import _sur_greedy_many_hostgamma

        ps, b, budgets, thetas = _case(33, 7, 8, 4, 0.3, 2.5)
        key = jax.random.key(21)
        fused = sur_greedy_many(ps, b, budgets, 4, key, thetas)
        host = _sur_greedy_many_hostgamma(ps, b, budgets, 4, key, thetas)
        for s, m in zip(host, fused):
            _assert_same(s, m)


class TestDonationSafety:
    """`donate_argnums` on the planner scan: bit-identical results, and
    the donated device buffers really are handed over (deleted)."""

    def test_donate_on_off_bit_identical(self):
        ps, b, budgets, thetas = _case(40, 6, 8, 4, 0.3, 2.0)
        key = jax.random.key(31)
        on = sur_greedy_many(ps, b, budgets, 4, key, thetas, donate=True)
        off = sur_greedy_many(ps, b, budgets, 4, key, thetas, donate=False)
        for s, m in zip(on, off):
            _assert_same(s, m)

    def test_donation_semantics_delete_usable_buffers(self):
        """The contract the `donation-contract` lint rule guards: when a
        donated input CAN alias an output, XLA deletes it and a host
        re-read raises. (Demonstrated on a minimal wrapper whose output
        shape matches — the planner/wave programs return reductions, see
        the companion test below.)"""
        import functools

        import jax.numpy as jnp

        donating = functools.partial(jax.jit, donate_argnums=(0,))(
            lambda x, y: x * 2.0 + y
        )
        x = jnp.ones((16, 16))
        y = jnp.ones((16, 16))
        out = donating(x, y)
        jax.block_until_ready(out)
        assert x.is_deleted() and not y.is_deleted()
        with pytest.raises(RuntimeError, match="deleted"):
            np.asarray(x)

    def test_planner_donation_is_declarative_on_reduction_outputs(self):
        """`_sur_greedy_scan` returns reductions (picks, counts, xi), so
        none of its donated staging tables can alias an output: XLA
        records them unusable at compile time and leaves the host-visible
        device arrays alive. Donation on this program is a declarative
        forward-compatible no-op — callers must still honor the contract,
        but committed inputs stay readable on this backend."""
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        from repro.core import selection as selection_mod
        from repro.core.mc import GroupedXiEstimator as GXE

        ps, b, budgets, thetas = _case(41, 2, 5, 3, 0.5, 1.5)
        key = jax.random.key(2)
        est = GXE(key, ps, 3, thetas)
        scr, b_p, _, _ = selection_mod._stage_groups(est, b, budgets, 8)
        for wrapper in (
            selection_mod._sur_greedy_scan,
            selection_mod._sur_greedy_scan_nodonate,
        ):
            with enable_x64(), selection_mod._quiet_donation():
                dev = {k: jnp.asarray(v) for k, v in scr.items()}
                dev_b = jnp.asarray(b_p)
                out = wrapper(
                    dev["resp"], dev["valid"], dev["w"], dev["empty"],
                    dev["theta"], dev["p"], dev_b, dev["budgets"],
                    dev["m"], num_classes=3, full=True,
                )
                jax.block_until_ready(out)
            # donate_argnums=(0, 1, 2) == (resp, valid, w): unusable for
            # aliasing here, so they survive either wrapper
            for name in ("resp", "valid", "w", "budgets"):
                assert not dev[name].is_deleted(), name
                np.asarray(dev[name])

    def test_host_scratch_survives_donation(self):
        """The serving path passes the module-level staging scratch as
        numpy: back-to-back plans reusing the same scratch buffers must
        stay correct (the jit donates its own transfer, not our arrays)."""
        ps, b, budgets, thetas = _case(42, 4, 6, 4, 0.4, 2.0)
        key = jax.random.key(13)
        first = sur_greedy_many(ps, b, budgets, 4, key, thetas)
        again = sur_greedy_many(ps, b, budgets, 4, key, thetas)
        for s, m in zip(first, again):
            _assert_same(s, m)


# ---------------------------------------------------------------------------
# Property: the batched greedy is invariant to group permutation
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=2, max_value=7),    # G
    st.integers(min_value=3, max_value=9),    # L
    st.integers(min_value=2, max_value=5),    # K
    st.integers(min_value=0, max_value=10_000),  # data seed
    st.integers(min_value=0, max_value=10_000),  # permutation seed
)
def test_group_permutation_invariance(G, L, K, seed, perm_seed):
    ps, b, budgets, thetas = _case(seed, G, L, K, 0.2, 2.0)
    key = jax.random.key(17)
    base = sur_greedy_many(ps, b, budgets, K, key, thetas)
    perm = np.random.default_rng(perm_seed).permutation(G)
    permuted = sur_greedy_many(
        ps[perm], b, budgets[perm], K, key, thetas[perm]
    )
    for i, g in enumerate(perm):
        _assert_same(base[g], permuted[i])
