"""Correctness of the perf-iteration features: bucketed causal attention,
int8 KV cache, shard_map expert parallelism (subprocess, 8 host devices)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import LM, ModelConfig
from repro.models.attention import bucketed_causal_attention, direct_attention


@pytest.mark.parametrize("buckets,window", [(4, 0), (8, 0), (8, 64)])
def test_bucketed_causal_matches_direct(buckets, window):
    rng = np.random.default_rng(buckets + window)
    q = jnp.asarray(rng.normal(0, 1, (2, 256, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (2, 256, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (2, 256, 2, 32)), jnp.float32)
    got = bucketed_causal_attention(q, k, v, window=window, block_kv=32, buckets=buckets)
    want = direct_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_int8_kv_decode_close_and_argmax_stable():
    cfg = ModelConfig(
        name="q8", family="dense", num_layers=3, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=256, kv_quant="int8",
        dtype="float32", remat=False, tie_embeddings=True,
    )
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 32), 0, 256)
    logits = model.forward(params, tokens)
    _, cache = jax.jit(model.prefill)(params, tokens[:, :-1])
    assert cache["segs"][0]["u0"]["k"].dtype == jnp.int8
    assert "k_scale" in cache["segs"][0]["u0"]
    dl, _ = jax.jit(model.decode_step)(params, cache, tokens[:, -1:])
    rel = float(jnp.max(jnp.abs(dl - logits[:, -1]))) / float(
        jnp.max(jnp.abs(logits[:, -1]))
    )
    assert rel < 0.05
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(dl, -1)), np.asarray(jnp.argmax(logits[:, -1], -1))
    )


def test_int8_kv_init_cache_shapes():
    cfg = ModelConfig(
        name="q8", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=256, kv_quant="int8",
        dtype="float32", remat=False,
    )
    cache = LM(cfg).init_cache(batch=3, cache_len=16, prefilled=15)
    u0 = cache["segs"][0]["u0"]
    assert u0["k"].dtype == jnp.int8 and u0["k_scale"].shape == (2, 3, 16, 2, 1)


_EP_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.distributed.sharding import AxisRules, use_rules
    from repro.models.moe import moe_mlp, moe_mlp_ep

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    T, D, F, E, k = 64, 32, 48, 8, 2
    x = jnp.asarray(rng.normal(0, 1, (T, D)), jnp.float32)
    rw = jnp.asarray(rng.normal(0, 1, (D, E)), jnp.float32)
    wg = jnp.asarray(rng.normal(0, 0.2, (E, D, F)), jnp.float32)
    wu = jnp.asarray(rng.normal(0, 0.2, (E, D, F)), jnp.float32)
    wd = jnp.asarray(rng.normal(0, 0.2, (E, F, D)), jnp.float32)

    # big capacity: no drops in either path -> outputs must match
    dense_out, dense_aux = moe_mlp(x, rw, wg, wu, wd, k, capacity_factor=16.0)
    with mesh:
        ep_out, ep_aux = jax.jit(
            lambda *a: moe_mlp_ep(*a, k=k, capacity_factor=16.0, mesh=mesh,
                                  batch_axes=("data",), expert_axis="model")
        )(x, rw, wg, wu, wd)
    diff = float(jnp.max(jnp.abs(dense_out - ep_out)))
    # gradient flows through the a2a pair
    def loss(x):
        y, _ = moe_mlp_ep(x, rw, wg, wu, wd, k=k, capacity_factor=16.0,
                          mesh=mesh, batch_axes=("data",), expert_axis="model")
        return jnp.sum(y * y)
    with mesh:
        g = jax.jit(jax.grad(loss))(x)
    print(json.dumps({
        "diff": diff, "aux_diff": abs(float(dense_aux) - float(ep_aux)),
        "grad_finite": bool(np.isfinite(np.asarray(g)).all()),
        "grad_norm": float(jnp.linalg.norm(g)),
    }))
    """
)


def test_moe_ep_matches_dense_dispatch():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _EP_SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(__file__)), timeout=540,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["diff"] < 1e-4, res
    # aux is a load-balance regularizer; the EP path averages per-shard
    # statistics (mean of products != product of means) — close, not equal
    assert res["aux_diff"] < 0.2, res
    assert res["grad_finite"] and res["grad_norm"] > 0
