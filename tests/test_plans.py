"""PlanService: hit/miss accounting, prewarm, invalidation on pool change."""
import dataclasses

import numpy as np

from repro.core.clustering import kmeans
from repro.core.estimation import SuccessProbEstimator
from repro.data import OracleWorkload
from repro.serving import (
    BatchScheduler,
    PlanService,
    PoolEngine,
    Request,
    ThriftRouter,
)


@dataclasses.dataclass
class TabularArm:
    name: str
    cost: float
    resp: np.ndarray

    def classify_batch(self, queries) -> np.ndarray:
        return self.resp[np.asarray(queries, np.int64)]

    def latency_s(self, batch: int) -> float:
        return 1e-6 * self.cost * batch


def _make(K=4, L=8, clusters=5, B=64, seed=3):
    wl = OracleWorkload(num_classes=K, num_clusters=clusters, num_arms=L, seed=seed)
    T, emb, _ = wl.response_table(60 * clusters, seed=seed + 1)
    assign, _ = kmeans(emb, clusters, seed=0)
    est = SuccessProbEstimator(T, emb, assign)
    rng = np.random.default_rng(seed + 2)
    qcid, qemb, qlab = wl.sample_queries(B, rng)
    R = np.stack(
        [
            wl.invoke_batch(a, qcid, qlab, np.random.default_rng(seed + 100 + a))
            for a in range(L)
        ]
    )
    engine = PoolEngine(
        [TabularArm(f"t{a}", float(wl.costs[a]), R[a]) for a in range(L)]
    )
    router = ThriftRouter(engine, est, num_classes=K)
    return est, engine, router, qemb


def test_plan_cache_hits_and_misses():
    est, engine, router, qemb = _make()
    budget = float(np.quantile(engine.costs, 0.6)) * 2
    B = qemb.shape[0]
    router.route_batch(np.arange(B), qemb, budget)
    s1 = router.plans.stats()
    assert s1["plan_misses"] > 0                       # cold cache built plans
    assert s1["plan_misses"] == s1["plan_cache_size"]
    router.route_batch(np.arange(B), qemb, budget)
    s2 = router.plans.stats()
    assert s2["plan_misses"] == s1["plan_misses"]      # warm: no new builds
    assert s2["plan_hits"] > s1["plan_hits"]
    assert s2["plan_cache_size"] == s1["plan_cache_size"]


def test_prewarm_ahead_of_traffic_and_hot_pairs():
    est, engine, router, qemb = _make()
    budget = float(np.quantile(engine.costs, 0.6)) * 2
    built = router.plans.prewarm(budgets=[budget])
    assert built == len(est.clusters)                  # every cluster planned
    B = qemb.shape[0]
    router.route_batch(np.arange(B), qemb, budget)
    s = router.plans.stats()
    assert s["plan_misses"] == 0                       # traffic fully warm
    assert s["plan_hits"] > 0
    hot = router.plans.hot_pairs(3)
    assert hot and all(b == budget for _, b in hot)
    # explicit-pairs mode builds exactly the requested plans
    other = budget * 1.5
    assert router.plans.prewarm(pairs=[(hot[0][0], other)]) == 1


def test_invalidation_on_pool_change():
    est, engine, router, qemb = _make()
    budget = float(np.quantile(engine.costs, 0.6)) * 2
    B = qemb.shape[0]
    res_before = router.route_batch(np.arange(B), qemb, budget)
    size_before = router.plans.stats()["plan_cache_size"]
    assert size_before > 0

    # re-price the cheapest arm above the budget: stale plans must not serve
    cheap = int(np.argmin(engine.costs))
    engine.arms[cheap].cost = budget * 10.0
    res_after = router.route_batch(np.arange(B), qemb, budget)
    s = router.plans.stats()
    assert s["plan_invalidations"] == 1
    assert s["plan_cache_size"] > 0                    # rebuilt, not stale
    assert all(cheap not in used for used in res_after.arms_used)
    assert any(cheap in used for used in res_before.arms_used)
    # selector snapshot re-pulled: budgets enforced against the new price
    assert (res_after.costs <= budget + 1e-12).all()
    # no further invalidation while the pool stays put
    router.route_batch(np.arange(B), qemb, budget)
    assert router.plans.stats()["plan_invalidations"] == 1


def test_prewarm_hot_pairs_survive_cost_invalidation():
    """No-arg prewarm after a re-pricing must rebuild the hottest pairs —
    the hot-pair snapshot is taken before the caches invalidate."""
    est, engine, router, qemb = _make()
    budget = float(np.quantile(engine.costs, 0.6)) * 2
    B = qemb.shape[0]
    router.route_batch(np.arange(B), qemb, budget)
    hot = set(router.plans.hot_pairs(16))
    assert hot
    engine.arms[0].cost = engine.arms[0].cost * 3.0   # re-price -> invalidate
    built = router.plans.prewarm()
    assert built == len(hot)                          # hot pairs re-planned
    assert router.plans.stats()["plan_invalidations"] == 1
    # the following batch routes entirely from the prewarmed cache
    before = router.plans.stats()["plan_misses"]
    router.route_batch(np.arange(B), qemb, budget)
    assert router.plans.stats()["plan_misses"] == before


def test_single_cluster_update_keeps_other_plans():
    """Re-estimating one cluster invalidates only that cluster's plans;
    the rest of the cache keeps hitting (per-cluster p-digest keys)."""
    est, engine, router, qemb = _make()
    budget = float(np.quantile(engine.costs, 0.6)) * 2
    B = qemb.shape[0]
    router.route_batch(np.arange(B), qemb, budget)
    misses_before = router.plans.stats()["plan_misses"]
    cid = int(next(iter(est.clusters)))
    est.update(cid, np.ones((4, len(engine.arms))))   # recalibrate one cluster
    router.route_batch(np.arange(B), qemb, budget)
    s = router.plans.stats()
    assert s["plan_invalidations"] == 1
    assert s["plan_misses"] == misses_before + 1      # only cid re-planned


def test_hot_pairs_track_traffic_through_fast_path():
    """Uniform-budget batches route via cached BatchTables, yet hot-pair
    counts must still reflect per-query traffic volume."""
    est, engine, router, qemb = _make()
    budget = float(np.quantile(engine.costs, 0.6)) * 2
    B = qemb.shape[0]
    for _ in range(3):
        router.route_batch(np.arange(B), qemb, budget)
    counts = router.plans._pair_counts
    assert sum(counts.values()) >= 3 * B              # per-query, not per-batch
    top_cluster = router.plans.hot_pairs(1)[0][0]
    idx = est.lookup_batch_indices(qemb)
    busiest = int(est.cluster_order[np.argmax(np.bincount(idx))])
    assert top_cluster == busiest


def test_shared_plan_service_across_routers():
    est, engine, router, qemb = _make()
    budget = float(np.quantile(engine.costs, 0.6)) * 2
    B = qemb.shape[0]
    router.route_batch(np.arange(B), qemb, budget)
    misses = router.plans.stats()["plan_misses"]
    # a second router bound to the same pool reuses the shared plans
    router2 = ThriftRouter(engine, est, num_classes=4, plan_service=router.plans)
    router2.route_batch(np.arange(B), qemb, budget)
    assert router.plans.stats()["plan_misses"] == misses


def test_out_of_band_p_hat_edit_needs_touch():
    """Direct p_hat assignment bypasses the version machinery; the
    documented escape hatch is estimator.touch(cid), after which stale
    plans can never serve."""
    est, engine, router, qemb = _make()
    budget = float(np.quantile(engine.costs, 0.6)) * 2
    cid = int(est.cluster_order[0])
    p0 = router.plans.plan(cid, budget)
    est.clusters[cid].p_hat = np.clip(est.clusters[cid].p_hat * 0.5, 0, 1)
    est.touch(cid)
    p1 = router.plans.plan(cid, budget)          # lazy key miss, no refresh
    assert p1 is not p0
    assert router.plans.refresh() is True        # detected + pruned
    assert router.plans.plan(cid, budget) is p1


def test_selector_cache_bounded_under_estimate_churn():
    """Continuous plan-visible estimate churn must not grow the selector's
    selection memo without bound (dead p-vector keys can never hit)."""
    est, engine, router, qemb = _make()
    budget = float(np.quantile(engine.costs, 0.6)) * 2
    cid = int(est.cluster_order[0])
    rng = np.random.default_rng(0)
    for _ in range(3):   # each churn: one dead selector entry + one live
        est.update(cid, (rng.random((2, len(engine.arms))) < 0.7).astype(float))
        router.plans.refresh()
        router.plans.plan(cid, budget)
    # trim_cache drops oldest-first once past the bound (dict order = age)
    sel = router.selector
    sel._cache.update({("pad", i): i for i in range(400)})
    over = len(sel._cache)
    cap = max(128, 4 * len(router.plans._cache))
    est.update(cid, np.ones((2, len(engine.arms))))
    router.plans.refresh()                        # prune path trims the memo
    assert len(sel._cache) == cap < over
    assert ("pad", 399) in sel._cache             # newest survive


def test_prewarm_compile_counts_buckets():
    est, engine, router, qemb = _make()
    n = router.prewarm_compile(16)
    assert n >= 1                                # one program per T bucket
    assert router.prewarm_compile(16, max_waves=1) == 1
    # ragged-traffic coverage: every smaller batch bucket compiles too
    assert router.prewarm_compile(16, max_waves=1, all_batch_buckets=True) == 2
    from repro.serving import ThriftRouter as TR
    pinned = TR(engine, est, num_classes=4, jit_waves=False)
    assert pinned.prewarm_compile(16) == 0       # reference plane: no-op


def test_scheduler_exposes_plan_stats_and_prewarm():
    est, engine, router, qemb = _make(B=16)
    budget = float(np.quantile(engine.costs, 0.6)) * 2
    sched = BatchScheduler(router, max_batch=16, max_wait_s=0.0)
    assert "plan_hits" in sched.stats and "plan_misses" in sched.stats
    built = sched.prewarm(budgets=[budget])
    assert built == len(est.clusters)
    for i in range(16):
        sched.submit(Request(payload=i, embedding=qemb[i], budget=budget))
    sched.flush()
    assert sched.stats["plan_misses"] == 0             # prewarmed
    assert sched.stats["plan_hits"] > 0
    assert sched.stats["plan_cache_size"] >= built


def test_plan_many_matches_plan_and_counts():
    """plan_many == per-pair plan(): same plans (bitwise), same hit/miss
    accounting, one batched build for a miss storm."""
    est, engine, router, _ = _make()
    est2, engine2, router2, _ = _make()
    budget = float(np.quantile(engine.costs, 0.6)) * 2
    pairs = [(int(c), budget) for c in est.cluster_order]

    serial = [router.plans.plan(c, b) for c, b in pairs]
    batched = router2.plans.plan_many(pairs)
    for s, m in zip(serial, batched):
        np.testing.assert_array_equal(s.order, m.order)
        np.testing.assert_array_equal(s.weights, m.weights)
        np.testing.assert_array_equal(s.residual, m.residual)
        assert s.planned == m.planned and s.empty == m.empty
    assert router.plans.stats() == router2.plans.stats()
    # warm lookups are pure hits, returning the cached objects
    again = router2.plans.plan_many(pairs)
    assert all(a is b for a, b in zip(again, batched))
    assert router2.plans.stats()["plan_misses"] == len(pairs)


def test_serial_and_batched_services_build_identical_plans():
    """PlanService(batched=False) is the serial baseline: bit-identical
    plans to the batched planner under the shared CRN seed."""
    est, engine, router, _ = _make()
    est2, engine2, router2, _ = _make()
    router2.plans.batched = False
    budget = float(np.quantile(engine.costs, 0.5)) * 2
    pairs = [(int(c), budget) for c in est.cluster_order]
    for pb, ps in zip(router.plans.plan_many(pairs), router2.plans.plan_many(pairs)):
        np.testing.assert_array_equal(pb.order, ps.order)
        np.testing.assert_array_equal(pb.weights, ps.weights)


def test_replan_stale_rebuilds_dropped_pairs_in_one_call():
    """The drift fast path: touch G clusters -> refresh prunes their plans
    -> replan_stale rebuilds exactly those pairs, counted as one batched
    replan."""
    est, engine, router, _ = _make()
    plans = router.plans
    budget = float(np.quantile(engine.costs, 0.6)) * 2
    pairs = [(int(c), budget) for c in est.cluster_order]
    plans.plan_many(pairs)
    size0 = len(plans._cache)

    drifted = [int(c) for c in est.cluster_order[:3]]
    for c in drifted:
        est.touch(c)
    rebuilt = plans.replan_stale(drifted)
    assert rebuilt == 3
    assert len(plans._cache) == size0                  # dropped then rebuilt
    s = plans.stats()
    assert s["plan_batch_replans"] == 1
    assert s["plan_batch_replanned"] == 3
    assert s["plan_stale_dropped"] == 3
    # the rebuilt plans serve as hits, at the new versions
    before = plans.stats()["plan_misses"]
    for c in drifted:
        plans.plan(c, budget)
    assert plans.stats()["plan_misses"] == before
