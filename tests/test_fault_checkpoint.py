"""Checkpoint/restart, elastic re-mesh planning, straggler detection."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.distributed.fault import (
    FaultTolerantDriver,
    HeartbeatMonitor,
    StragglerMitigator,
    plan_elastic_remesh,
    rebatch_for_mesh,
)
from repro.models import LM
from repro.training import OptimizerConfig, init_train_state, make_train_step


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("smollm-135m")
    model = LM(cfg)
    params, opt = init_train_state(model, jax.random.key(0))
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    mgr.save(10, {"params": params, "opt": opt})
    step, restored = mgr.restore_latest({"params": params, "opt": opt})
    assert step == 10
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves({"params": params, "opt": opt})):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    state = {"x": np.arange(4)}
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.list_steps() == [3, 4]
    step, _ = mgr.restore_latest(state)
    assert step == 4


def test_checkpoint_skips_corrupt(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=5)
    state = {"x": np.arange(4)}
    mgr.save(1, state)
    mgr.save(2, state)
    # corrupt the newest shard
    with open(os.path.join(str(tmp_path), "step_000000002", "shard_0.npz"), "wb") as f:
        f.write(b"garbage")
    step, restored = mgr.restore_latest(state)
    assert step == 1
    np.testing.assert_array_equal(restored["x"], state["x"])


def test_restart_resumes_training(tmp_path):
    """Crash after step k -> restore -> continue: deterministic state match."""
    cfg = get_smoke_config("smollm-135m")
    model = LM(cfg)
    step_fn = jax.jit(make_train_step(model, OptimizerConfig(lr=1e-3, warmup_steps=1)))
    rng = np.random.default_rng(0)
    batches = [
        {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)}
        for _ in range(6)
    ]
    params, opt = init_train_state(model, jax.random.key(0))
    mgr = CheckpointManager(str(tmp_path))
    driver = FaultTolerantDriver(mgr, save_every=2)

    # run 1: steps 0..3, checkpointing every 2 (crash after step 3)
    p, o = params, opt
    for s in range(4):
        p, o, _ = step_fn(p, o, batches[s])
        driver.maybe_save(s, {"params": p, "opt": o})
    # run 2: restore (latest is step 2) and replay 3..5
    state, start = driver.restore({"params": params, "opt": opt})
    assert start == 3
    p2, o2 = state["params"], state["opt"]
    for s in range(start, 6):
        p2, o2, _ = step_fn(p2, o2, batches[s])
    # reference: uninterrupted run
    pr, orr = params, opt
    for s in range(6):
        pr, orr, _ = step_fn(pr, orr, batches[s])
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(pr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_heartbeat_detection():
    mon = HeartbeatMonitor(num_workers=4, timeout_s=10.0)
    now = 1000.0
    for w in range(4):
        mon.beat(w, t=now)
    mon.beat(2, t=now + 50)
    assert mon.dead_workers(now=now + 55) == [0, 1, 3]


def test_elastic_remesh_plan():
    shape = {"pod": 2, "data": 16, "model": 16}
    new = plan_elastic_remesh(shape, failed_hosts=[5], hosts_per_data_row=1)
    assert new == {"pod": 2, "data": 15, "model": 16}
    assert plan_elastic_remesh(shape, []) == shape
    assert rebatch_for_mesh(256, 16, 15) == 240


def test_straggler_detection():
    mit = StragglerMitigator(num_workers=4, threshold=2.0)
    for _ in range(5):
        mit.record_step([1.0, 1.1, 0.9, 5.0])
    assert mit.stragglers() == [3]
    assert mit.hedge_plan([0, 3, 2], 3) == [0, 2, 3]
