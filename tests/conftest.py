"""Tier-1 runs under the thriftlint tracer-leak guard.

`jax.check_tracer_leaks` is enabled for the whole suite (the runtime
counterpart of the static jit-purity rule): any test that smuggles a
tracer into host state fails immediately instead of corrupting a later
test through a stale reference.  Opt out with THRIFTLINT_TRACER_GUARD=0
(e.g. for profiling runs — the guard adds gc-based bookkeeping to every
trace).
"""
from repro.analysis import install_tracer_guard

TRACER_GUARD_INSTALLED = install_tracer_guard()
