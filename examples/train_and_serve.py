"""End-to-end driver: TRAIN a pool of real JAX models (~2M-60M params,
a few hundred steps each), CALIBRATE their success probabilities on a
historical split, then SERVE batched classification queries through the
ThriftLLM router with per-query budgets — the paper's Figure-1 pipeline
with live models, plus checkpoint/restart fault tolerance.

Run:  PYTHONPATH=src python examples/train_and_serve.py [--steps 300]
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.estimation import SuccessProbEstimator
from repro.data import DataPipeline, make_token_task
from repro.models import LM, ModelConfig
from repro.serving import LMArm, PoolEngine, ThriftRouter
from repro.training import OptimizerConfig, init_train_state, make_train_step

K = 8          # classes
SEQ = 64
VOCAB = 512


ARMS = [
    # (name, d_model, layers, heads, train_steps)
    ("nano", 32, 1, 2, 120),
    ("micro", 48, 2, 4, 200),
    ("tiny", 64, 2, 4, 300),
    ("small", 96, 3, 4, 300),
]


def train_arm(name, d_model, layers, heads, steps, data, ckpt_dir, batch=32):
    cfg = ModelConfig(
        name=name, family="dense", num_layers=layers, d_model=d_model,
        num_heads=heads, num_kv_heads=max(1, heads // 2), d_ff=2 * d_model,
        vocab_size=VOCAB, dtype="float32", remat=False, tie_embeddings=True,
    )
    model = LM(cfg)
    params, opt = init_train_state(model, jax.random.key(hash(name) % 2**31))
    step_fn = jax.jit(
        make_train_step(model, OptimizerConfig(lr=6e-3, warmup_steps=20, total_steps=steps))
    )
    mgr = CheckpointManager(os.path.join(ckpt_dir, name), keep_last=2)

    toks = data["tokens"]
    n = toks.shape[0]

    def make_batch(s):
        i = (s * batch) % (n - batch)
        return {"tokens": toks[i : i + batch]}

    pipe = DataPipeline(make_batch, prefetch=2)
    start, losses = 0, []
    t0 = time.time()
    restored_step, state = mgr.restore_latest({"params": params, "opt": opt})
    if restored_step is not None:
        params, opt = state["params"], state["opt"]
        start = restored_step + 1
        print(f"  [{name}] resumed from checkpoint step {restored_step}")
    for s in range(start, steps):
        b = next(pipe)
        params, opt, m = step_fn(params, opt, {"tokens": jnp.asarray(b["tokens"])})
        losses.append(float(m["loss"]))
        if s % 100 == 0 and s:
            mgr.save(s, {"params": params, "opt": opt})
    pipe.close()
    print(
        f"  [{name}] {cfg.param_count()/1e6:.2f}M params, {steps} steps in "
        f"{time.time()-t0:.1f}s, loss {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f}"
    )
    return LMArm(name, model, params, data["class_token_ids"], tokens_per_query=SEQ)


def embed_queries(tokens):
    return np.stack([np.bincount(t, minlength=VOCAB) for t in tokens]).astype(float)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=0, help="override per-arm steps")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpts")
    args = ap.parse_args()

    print("== 1. train the model pool ==")
    data = make_token_task(K, SEQ, VOCAB, n=4096, seed=0)
    arms = []
    for name, d, l, h, steps in ARMS:
        arms.append(
            train_arm(name, d, l, h, args.steps or steps, data, args.ckpt)
        )
    engine = PoolEngine(arms)

    print("\n== 2. calibrate success probabilities (Section 3.1) ==")
    hist = make_token_task(K, SEQ, VOCAB, n=1024, seed=1)
    T = np.zeros((1024, len(arms)))
    for a, arm in enumerate(arms):
        T[:, a] = arm.classify_batch(hist["tokens"]) == hist["labels"]
    for arm, acc in zip(arms, T.mean(0)):
        print(f"  {arm.name:6s} acc={acc:.3f} cost={arm.cost:.3e} USD/query")
    est = SuccessProbEstimator(T, embed_queries(hist["tokens"]), np.zeros(1024, np.int64))

    print("\n== 3. serve with ThriftLLM under per-query budgets ==")
    router = ThriftRouter(engine, est, num_classes=K)
    test = make_token_task(K, SEQ, VOCAB, n=512, seed=2)
    temb = embed_queries(test["tokens"])
    print(f"{'budget':>12} {'accuracy':>9} {'mean cost':>11} {'saving':>7}")
    for mult in [1.2, 2.5, 5.0, 100.0]:
        budget = float(np.sort(engine.costs)[0]) * mult
        res = router.route_batch(test["tokens"], temb, budget)
        acc = (res.predictions == test["labels"]).mean()
        saving = 1 - res.costs.sum() / max(res.planned_costs.sum(), 1e-15)
        assert (res.costs <= budget + 1e-15).all()
        print(f"{budget:12.3e} {acc:9.3f} {res.costs.mean():11.3e} {saving:6.1%}")


if __name__ == "__main__":
    main()
