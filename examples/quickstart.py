"""Quickstart: budget-constrained ensemble selection on a synthetic pool.

Builds a 12-arm pool (Table-4-style price/quality spread), estimates success
probabilities from historical responses, and answers queries with ThriftLLM
at several budgets — printing the accuracy/cost frontier plus the adaptive
early-stop saving.

Run:  PYTHONPATH=src python examples/quickstart.py
Tiny (smoke-tested by tests/test_examples.py):
      PYTHONPATH=src python examples/quickstart.py --queries 80 --history 300
"""
import argparse

import numpy as np

from repro.core.clustering import kmeans
from repro.core.estimation import SuccessProbEstimator
from repro.data import OracleWorkload
from repro.serving import OracleArm, PoolEngine, ThriftRouter


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--queries", type=int, default=1000,
                    help="test queries per budget")
    ap.add_argument("--history", type=int, default=3000,
                    help="historical responses for calibration")
    args = ap.parse_args(argv)

    # --- pool: 12 arms, stronger = pricier; 6 query classes, K=4 labels
    wl = OracleWorkload(num_classes=4, num_clusters=6, num_arms=12, seed=0)
    engine = PoolEngine([OracleArm(f"llm-{i}", wl, i, seed=9) for i in range(12)])
    print("pool costs (USD/query):", np.round(engine.costs, 7))

    # --- calibrate from historical responses (Section 3.1)
    T, emb, _ = wl.response_table(args.history, seed=1)
    assign, _ = kmeans(emb, 6, seed=0)
    est = SuccessProbEstimator(T, emb, assign)
    router = ThriftRouter(engine, est, num_classes=4)

    # --- test queries
    rng = np.random.default_rng(42)
    cid, qemb, labels = wl.sample_queries(args.queries, rng)
    queries = list(zip(cid, labels))

    print(f"\n{'budget':>12} {'accuracy':>9} {'mean cost':>11} {'saving':>7} {'arms':>5}")
    for budget in [1e-5, 5e-5, 1e-4, 5e-4, 1e-3]:
        res = router.route_batch(queries, qemb, budget)
        acc = (res.predictions == labels).mean()
        saving = 1 - res.costs.sum() / max(res.planned_costs.sum(), 1e-15)
        n_arms = np.mean([len(a) for a in res.arms_used])
        assert (res.costs <= budget + 1e-15).all()
        print(f"{budget:12.0e} {acc:9.3f} {res.costs.mean():11.3e} {saving:6.1%} {n_arms:5.1f}")

    # --- compare against the strongest affordable single arm at mid budget
    budget = 1e-4
    res = router.route_batch(queries, qemb, budget)
    best = int(np.argmax(np.where(engine.costs <= budget, wl.p_true.mean(0), -1)))
    single = np.array(
        [wl.invoke(best, int(c), int(l), rng) == l for c, l in queries]
    ).mean()
    print(f"\nat budget {budget:.0e}: ThriftLLM={np.mean(res.predictions == labels):.3f} "
          f"vs best single affordable arm={single:.3f}")


if __name__ == "__main__":
    main()
