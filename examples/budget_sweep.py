"""Budget sweep reproducing the shape of paper Figures 4 and 6:
accuracy-vs-cost frontier of ThriftLLM against the baselines (GreedyLLM,
FrugalGPT-style cascade, LLM-Blender-style use-all, top-k weighted, best
single arm), and the adaptive (Alg. 3) cost saving vs plain SurGreedyLLM.

Run:  PYTHONPATH=src python examples/budget_sweep.py
Tiny (smoke-tested by tests/test_examples.py):
      PYTHONPATH=src python examples/budget_sweep.py --queries 30 --history 300 \
          --budgets 1e-4 5e-4
"""
import argparse

import numpy as np

import jax

from repro.core import (
    FrugalCascade,
    adaptive_invoke,
    blender_all,
    single_best,
    sur_greedy,
    topk_weighted,
)
from repro.core.belief import aggregate_predict
from repro.core.clustering import kmeans
from repro.core.estimation import SuccessProbEstimator
from repro.data import OracleWorkload
from repro.serving import OracleArm, PoolEngine, ThriftRouter

BUDGETS = [1e-5, 5e-5, 1e-4, 5e-4, 1e-3]


def run_baseline_agg(chosen, wl, p_hat, queries, rng, K, costs):
    """Invoke a fixed subset on every query + ML aggregation."""
    acc, cost = 0, 0.0
    for cid, label in queries:
        resp = [wl.invoke(int(a), int(cid), int(label), rng) for a in chosen]
        pred = aggregate_predict(np.asarray(resp), p_hat[chosen], K, p_all=p_hat)
        acc += pred == label
        cost += costs[chosen].sum()
    return acc / len(queries), cost / len(queries)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--queries", type=int, default=600)
    ap.add_argument("--history", type=int, default=3000)
    ap.add_argument("--budgets", type=float, nargs="*", default=BUDGETS)
    args = ap.parse_args(argv)
    budgets = list(args.budgets)

    K = 4
    wl = OracleWorkload(num_classes=K, num_clusters=6, num_arms=12, seed=0)
    engine = PoolEngine([OracleArm(f"llm{i}", wl, i, seed=5) for i in range(12)])
    costs = engine.costs

    T, emb, _ = wl.response_table(args.history, seed=1)
    assign, _ = kmeans(emb, 6, seed=0)
    est = SuccessProbEstimator(T, emb, assign)
    router = ThriftRouter(engine, est, num_classes=K)

    rng = np.random.default_rng(7)
    cid, qemb, labels = wl.sample_queries(args.queries, rng)
    queries = list(zip(cid, labels))
    cl_of = est.lookup_batch(qemb)

    print(f"{'budget':>9} | {'Thrift':>14} | {'SurGreedy':>14} | {'cascade':>14} | "
          f"{'top-k':>14} | {'single':>14}")
    print(f"{'':>9} | " + " | ".join([f"{'acc':>6} {'cost':>7}"] * 5))
    for budget in budgets:
        # --- ThriftLLM (adaptive)
        res = router.route_batch(queries, qemb, budget)
        th = ((res.predictions == labels).mean(), res.costs.mean())

        # --- SurGreedyLLM (no adaptive early stop): planned-cost invocation
        sg_acc, sg_cost = 0.0, 0.0
        inv_rng = np.random.default_rng(11)
        for (q, c) in zip(queries, cl_of):
            p = est.clusters[int(c)].p_hat
            sel = router.selector.select(p, K, budget)
            a, co = run_baseline_agg(np.asarray(sel.chosen, int), wl, p, [q], inv_rng, K, costs)
            sg_acc += a
            sg_cost += co
        sg = (sg_acc / len(queries), sg_cost / len(queries))

        # --- FrugalGPT-style cascade (strict per-query budget for fairness)
        casc = FrugalCascade(costs, margin=2.0, strict=True)
        c_acc, c_cost = 0.0, 0.0
        inv_rng = np.random.default_rng(13)
        for (cidq, label), c in zip(queries, cl_of):
            p = est.clusters[int(c)].p_hat
            r = casc.answer(
                p, K, budget,
                lambda a: wl.invoke(a, int(cidq), int(label), inv_rng),
            )
            c_acc += r.prediction == label
            c_cost += r.cost
        ca = (c_acc / len(queries), c_cost / len(queries))

        # --- top-k weighted under budget (LLM-Ensemble-ish)
        inv_rng = np.random.default_rng(17)
        tk_acc, tk_cost = 0.0, 0.0
        for (q, c) in zip(queries, cl_of):
            p = est.clusters[int(c)].p_hat
            chosen = topk_weighted(p, costs, budget)
            a, co = run_baseline_agg(chosen, wl, p, [q], inv_rng, K, costs)
            tk_acc += a
            tk_cost += co
        tk = (tk_acc / len(queries), tk_cost / len(queries))

        # --- best affordable single arm
        inv_rng = np.random.default_rng(19)
        sb_acc, sb_cost = 0.0, 0.0
        for (q, c) in zip(queries, cl_of):
            p = est.clusters[int(c)].p_hat
            chosen = single_best(p, costs, budget)
            a, co = run_baseline_agg(chosen, wl, p, [q], inv_rng, K, costs)
            sb_acc += a
            sb_cost += co
        sb = (sb_acc / len(queries), sb_cost / len(queries))

        row = " | ".join(f"{a:6.3f} {c:7.1e}" for a, c in (th, sg, ca, tk, sb))
        print(f"{budget:9.0e} | {row}")

    # --- LLM-Blender-style: all arms, majority fusion, budget-unaware
    inv_rng = np.random.default_rng(23)
    bl_acc = 0.0
    for (cidq, label) in queries:
        r = blender_all(
            wl.p_true.mean(0), K,
            lambda a: wl.invoke(a, int(cidq), int(label), inv_rng), costs,
        )
        bl_acc += r.prediction == label
    print(f"\nLLM-Blender-style (all 12 arms, majority): acc={bl_acc/len(queries):.3f} "
          f"cost={costs.sum():.1e} (budget-unaware)")


if __name__ == "__main__":
    main()
